//! Loom model of the distributed coordinator's shard rendezvous
//! (DESIGN.md §12): the [`ShardTracker`] state machine under racing
//! completions, worker failures, and close.
//!
//! Invariants checked here are exactly the ones the bitwise-determinism
//! argument leans on:
//!
//! * **no shard double-reduced** — `complete` is first-wins, so a struck
//!   straggler finishing after its shard was reassigned contributes
//!   nothing;
//! * **no shard dropped** — `fail_worker` racing a completion leaves every
//!   shard in exactly one of {completed, orphaned}, never limbo;
//! * **close linearizes** — a completion racing `close` either lands (and
//!   is visible in `take_results`) or is rejected, with the boolean return
//!   agreeing with what the coordinator later observes.
//!
//! Build with `RUSTFLAGS="--cfg loom" cargo test -p dlrt --test
//! loom_dist`. Without `--cfg loom` this compiles to an empty test
//! binary. The in-tree `loom` shim explores perturbed schedules rather
//! than exhaustive DPOR — see rust/shims/loom.
#![cfg(loom)]

use dlrt::exec::dist::ShardTracker;
use loom::sync::Arc;
use loom::thread;
use std::time::Duration;

/// Two workers race to complete the same shard (the reassignment double-
/// fire): exactly one completion is accepted and its value is the one
/// that surfaces.
#[test]
fn racing_completions_reduce_a_shard_exactly_once() {
    loom::model(|| {
        let t: Arc<ShardTracker<u32>> = Arc::new(ShardTracker::new(1));
        let orphans = t.take_orphans();
        assert_eq!(orphans, vec![0], "all shards start orphaned");
        assert!(t.assign(0, 0));
        let a = {
            let t = Arc::clone(&t);
            thread::spawn(move || t.complete(0, 111))
        };
        let b = {
            let t = Arc::clone(&t);
            thread::spawn(move || t.complete(0, 222))
        };
        let a = a.join().expect("first completer");
        let b = b.join().expect("second completer");
        assert!(a ^ b, "exactly one completion must win (got {a} and {b})");
        assert!(t.is_complete());
        let results = t.take_results().expect("complete tracker yields results");
        if a {
            assert_eq!(results, vec![111]);
        } else {
            assert_eq!(results, vec![222]);
        }
        // the winner's shard can never be re-assigned afterwards
        assert!(!t.assign(0, 1), "completed shard must reject assignment");
    });
}

/// A worker failure races one of its own completions: whatever the
/// interleaving, shard 0 ends completed (exactly once) and shard 1 ends
/// orphaned — nothing is lost, nothing is duplicated, and draining the
/// orphans finishes the sweep.
#[test]
fn fail_worker_racing_completion_never_loses_or_duplicates_a_shard() {
    loom::model(|| {
        let t: Arc<ShardTracker<u32>> = Arc::new(ShardTracker::new(2));
        let _ = t.take_orphans();
        assert!(t.assign(0, 0));
        assert!(t.assign(1, 0));
        let completer = {
            let t = Arc::clone(&t);
            thread::spawn(move || t.complete(0, 10))
        };
        let failer = {
            let t = Arc::clone(&t);
            thread::spawn(move || t.fail_worker(0))
        };
        let landed = completer.join().expect("completer");
        let orphaned = failer.join().expect("failer");
        assert!(landed, "no competitor: the completion must land");
        assert!(
            orphaned == 1 || orphaned == 2,
            "fail_worker must orphan the worker's pending shards (got {orphaned})"
        );
        // shard 1 is pending either way; shard 0 must NOT be re-runnable
        let orphans = t.take_orphans();
        assert_eq!(orphans, vec![1], "exactly the unfinished shard is orphaned");
        assert!(t.assign(1, 1));
        assert!(t.complete(1, 99));
        let results = t.take_results().expect("drained tracker yields results");
        assert_eq!(results, vec![10, 99]);
    });
}

/// A completion races `close`: the boolean return must agree with what
/// the coordinator observes afterwards — landed-and-visible, or
/// rejected-and-absent. Either way every waiter wakes and the tracker is
/// finished.
#[test]
fn close_linearizes_against_completion() {
    loom::model(|| {
        let t: Arc<ShardTracker<u32>> = Arc::new(ShardTracker::new(1));
        let _ = t.take_orphans();
        assert!(t.assign(0, 0));
        let completer = {
            let t = Arc::clone(&t);
            thread::spawn(move || t.complete(0, 5))
        };
        let closer = {
            let t = Arc::clone(&t);
            thread::spawn(move || t.close())
        };
        let landed = completer.join().expect("completer");
        closer.join().expect("closer");
        assert!(t.is_closed());
        assert!(t.is_finished(), "closed tracker must end every wait loop");
        match t.take_results() {
            Some(results) => {
                assert!(landed, "results visible yet the completion reported rejection");
                assert_eq!(results, vec![5]);
            }
            None => assert!(!landed, "completion reported accepted yet results are absent"),
        }
        // post-close everything bounces
        assert!(!t.assign(0, 1));
        assert!(!t.complete(0, 7));
    });
}

/// `wait_tick` racing a completion must never hang: it returns on the
/// notification (or the timeout backstop) and the main loop then sees
/// the finished tracker.
#[test]
fn wait_tick_wakes_on_completion_and_never_hangs() {
    loom::model(|| {
        let t: Arc<ShardTracker<u32>> = Arc::new(ShardTracker::new(1));
        let _ = t.take_orphans();
        assert!(t.assign(0, 0));
        let completer = {
            let t = Arc::clone(&t);
            thread::spawn(move || {
                assert!(t.complete(0, 1));
            })
        };
        // bounded wait: either the notify lands or the timeout fires —
        // both return control to the reassignment loop
        t.wait_tick(Duration::from_millis(1));
        completer.join().expect("completer");
        assert!(t.is_finished());
        assert_eq!(t.take_results().expect("results"), vec![1]);
    });
}
