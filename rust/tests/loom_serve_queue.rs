//! Loom model of the serve queue's push/pop/close protocol (DESIGN.md
//! §11): the bounded MPMC deadline queue must deliver every accepted item
//! exactly once, linearize push against close (an item is either rejected
//! or drained — never silently dropped), and never hang a consumer once
//! the queue is closed and empty.
//!
//! Build with `RUSTFLAGS="--cfg loom" cargo test -p dlrt --test
//! loom_serve_queue`. Without `--cfg loom` this target compiles to an
//! empty test binary. The in-tree `loom` shim explores perturbed
//! schedules rather than exhaustive DPOR — see rust/shims/loom.
#![cfg(loom)]

use dlrt::serve::queue::{BoundedQueue, Drained, Push};
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;
use std::time::{Duration, Instant};

/// A deadline far enough out that nothing expires inside the model.
fn far() -> Instant {
    Instant::now() + Duration::from_secs(3600)
}

/// Two producers race a consumer and a close: every item the producers
/// saw accepted comes out of pop_batch exactly once, and the consumer
/// terminates.
#[test]
fn accepted_items_pop_exactly_once_across_close() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(8));
        let accepted = Arc::new(AtomicUsize::new(0));
        let producers: Vec<_> = (0..2usize)
            .map(|t| {
                let q = Arc::clone(&q);
                let accepted = Arc::clone(&accepted);
                thread::spawn(move || {
                    for i in 0..3usize {
                        if let Push::Accepted = q.push(far(), t * 10 + i) {
                            accepted.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        let consumer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let mut got: Vec<usize> = Vec::new();
                loop {
                    match q.pop_batch(2, &Instant::now, None) {
                        Drained::Closed => return got,
                        Drained::Batch { serve, expired } => {
                            assert!(expired.is_empty(), "far-future deadlines must not expire");
                            got.extend(serve.into_iter().map(|p| p.item));
                        }
                    }
                }
            })
        };
        for p in producers {
            p.join().expect("producer");
        }
        q.close();
        let got = consumer.join().expect("consumer");
        assert_eq!(got.len(), accepted.load(Ordering::Relaxed), "lost or phantom item");
        let mut dedup = got.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), got.len(), "duplicate delivery: {got:?}");
    });
}

/// Push races close: the push is either accepted (and then drained after
/// the close) or rejected with `Push::Closed` — the two outcomes are the
/// only ones, and they agree with what a later consumer observes.
#[test]
fn push_racing_close_never_loses_an_accepted_item() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(4));
        let pusher = {
            let q = Arc::clone(&q);
            thread::spawn(move || match q.push(far(), 1usize) {
                Push::Accepted => true,
                Push::Closed(_) => false,
                Push::Full(_) => panic!("capacity 4 cannot be full after one push"),
            })
        };
        let closer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.close())
        };
        let accepted = pusher.join().expect("pusher");
        closer.join().expect("closer");
        let mut got = 0usize;
        loop {
            match q.pop_batch(4, &Instant::now, None) {
                Drained::Closed => break,
                Drained::Batch { serve, expired } => got += serve.len() + expired.len(),
            }
        }
        assert_eq!(got, usize::from(accepted), "push/close linearization violated");
        assert!(matches!(q.push(far(), 9usize), Push::Closed(_)));
    });
}

/// Two consumers split a closed queue's backlog without duplicating or
/// dropping anything, and both terminate.
#[test]
fn two_consumers_split_items_without_duplication() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(16));
        for i in 0..6usize {
            assert!(matches!(q.push(far(), i), Push::Accepted));
        }
        q.close();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut got: Vec<usize> = Vec::new();
                    loop {
                        match q.pop_batch(2, &Instant::now, None) {
                            Drained::Closed => return got,
                            Drained::Batch { serve, .. } => {
                                got.extend(serve.into_iter().map(|p| p.item));
                            }
                        }
                    }
                })
            })
            .collect();
        let mut all: Vec<usize> = Vec::new();
        for c in consumers {
            all.extend(c.join().expect("consumer"));
        }
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    });
}
