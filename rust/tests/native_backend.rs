//! Native-backend correctness.
//!
//! * Finite-difference gradient checks of the `kl_grads` / `s_grads`
//!   services on small custom architectures — one fully-connected, one
//!   convolutional (im2col + max-pool path) — the analytic `∂K`, `∂L`,
//!   `∂S`, `∂bias` (and a dense `∂W` spot check) must match central
//!   differences of the `forward` loss entry by entry.
//! * End-to-end smokes: rank-adaptive training through `ModelState::Kls`
//!   must decrease the loss and truncate ranks below init, on toy data
//!   (MLP) and on LeNet5 (conv) — the Algorithm 1 loop running entirely on
//!   the hermetic pure-Rust path.
//! * Preset/registry consistency: every preset that declares
//!   `backend = "native"` must resolve its architecture in the native
//!   registry, so a preset/registry drift cannot silently recur.

use dlrt::backend::{ComputeBackend, LayerFactors, NativeBackend};
use dlrt::config::{presets, DataSource};
use dlrt::coordinator::{ModelState, Trainer};
use dlrt::data::Batch;
use dlrt::dlrt::LowRankFactors;
use dlrt::linalg::{Matrix, Rng};
use dlrt::runtime::{ArchInfo, LayerInfo};

const ARCH: &str = "fd_tiny";
const DIM: usize = 9;
const CLASSES: usize = 5;
const BATCH: usize = 8;

fn dense_layer(m: usize, n: usize) -> LayerInfo {
    LayerInfo {
        kind: "dense".into(),
        m,
        n,
        in_ch: 0,
        out_ch: 0,
        ksize: 0,
        in_h: 0,
        in_w: 0,
        pool: false,
        out_h: 0,
        out_w: 0,
    }
}

fn conv_layer(
    in_ch: usize,
    out_ch: usize,
    ksize: usize,
    in_h: usize,
    in_w: usize,
    pool: bool,
) -> LayerInfo {
    let (hp, wp) = (in_h - ksize + 1, in_w - ksize + 1);
    let (out_h, out_w) = if pool { (hp / 2, wp / 2) } else { (hp, wp) };
    LayerInfo {
        kind: "conv".into(),
        m: out_ch,
        n: in_ch * ksize * ksize,
        in_ch,
        out_ch,
        ksize,
        in_h,
        in_w,
        pool,
        out_h,
        out_w,
    }
}

fn backend() -> NativeBackend {
    let dense_arch = ArchInfo {
        layers: vec![dense_layer(7, DIM), dense_layer(CLASSES, 7)],
        input_dim: DIM,
        num_classes: CLASSES,
        image_hwc: None,
    };
    // conv FD net: 7x7x1 -> conv(1->3, k3) 5x5x3 -> pool 2x2x3 = 12 -> head
    let conv_arch = ArchInfo {
        layers: vec![conv_layer(1, 3, 3, 7, 7, true), dense_layer(CLASSES, 12)],
        input_dim: 49,
        num_classes: CLASSES,
        image_hwc: Some([7, 7, 1]),
    };
    NativeBackend::new()
        .with_arch(ARCH, dense_arch, BATCH)
        .with_arch(CONV_ARCH, conv_arch, BATCH)
}

const CONV_ARCH: &str = "fd_conv";

fn tiny_batch_dim(dim: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    Batch {
        x: (0..BATCH * dim).map(|_| rng.normal()).collect(),
        y: (0..BATCH).map(|_| rng.below(CLASSES) as i32).collect(),
        w: vec![1.0; BATCH],
        count: BATCH,
    }
}

fn tiny_batch(seed: u64) -> Batch {
    tiny_batch_dim(DIM, seed)
}

fn tiny_layers(seed: u64) -> Vec<LowRankFactors> {
    let mut rng = Rng::new(seed);
    vec![
        LowRankFactors::random(7, DIM, 3, &mut rng),
        LowRankFactors::random(CLASSES, 7, 4, &mut rng),
    ]
}

fn conv_layers(seed: u64) -> Vec<LowRankFactors> {
    let mut rng = Rng::new(seed);
    vec![
        LowRankFactors::random(3, 9, 2, &mut rng),
        LowRankFactors::random(CLASSES, 12, 4, &mut rng),
    ]
}

fn refs(layers: &[LowRankFactors]) -> Vec<LayerFactors<'_>> {
    layers
        .iter()
        .map(|f| LayerFactors { u: &f.u, s: &f.s, v: &f.v, bias: &f.bias })
        .collect()
}

fn loss_of(be: &NativeBackend, arch: &str, layers: &[LowRankFactors], batch: &Batch) -> f32 {
    be.forward(arch, &refs(layers), batch).unwrap().loss
}

/// Central difference of `loss` along one entry of a factor, selected and
/// perturbed by `apply`.
fn central_diff(
    be: &NativeBackend,
    arch: &str,
    layers: &[LowRankFactors],
    batch: &Batch,
    eps: f32,
    apply: impl Fn(&mut Vec<LowRankFactors>, f32),
) -> f32 {
    let mut plus = layers.to_vec();
    apply(&mut plus, eps);
    let mut minus = layers.to_vec();
    apply(&mut minus, -eps);
    (loss_of(be, arch, &plus, batch) - loss_of(be, arch, &minus, batch)) / (2.0 * eps)
}

fn assert_close(analytic: f32, numeric: f32, what: &str) {
    let tol = 2e-3 + 2e-2 * numeric.abs();
    assert!(
        (analytic - numeric).abs() <= tol,
        "{what}: analytic {analytic} vs finite-difference {numeric}"
    );
}

/// Collects per-entry (analytic, numeric) pairs of one FD sweep.
///
/// `max_outliers = 0` demands every entry match. The conv checks pass a
/// small allowance instead: central differences are one-sided near a
/// max-pool argmax tie or a ReLU zero crossing, so an *isolated* entry may
/// legitimately disagree; a real gradient bug (wrong patch/pool index
/// mapping) corrupts entries wholesale and still fails the cap.
struct FdReport {
    what: String,
    checked: usize,
    failures: Vec<String>,
}

impl FdReport {
    fn new(what: &str) -> FdReport {
        FdReport { what: what.into(), checked: 0, failures: Vec::new() }
    }

    fn check(&mut self, analytic: f32, numeric: f32, entry: &str) {
        self.checked += 1;
        let tol = 2e-3 + 2e-2 * numeric.abs();
        if (analytic - numeric).abs() > tol {
            self.failures.push(format!("{entry}: analytic {analytic} vs fd {numeric}"));
        }
    }

    fn finish(self, max_outliers: usize) {
        assert!(
            self.failures.len() <= max_outliers,
            "{}: {}/{} entries off (allowed {}):\n{}",
            self.what,
            self.failures.len(),
            self.checked,
            max_outliers,
            self.failures.join("\n")
        );
    }
}

/// FD-check every ∂K and ∂L entry of `kl_grads` against the `forward` loss.
fn check_kl_finite_differences(
    be: &NativeBackend,
    arch: &str,
    layers: &[LowRankFactors],
    batch: &Batch,
    eps: f32,
    max_outliers: usize,
) {
    let kl = be.kl_grads(arch, &refs(layers), batch).unwrap();
    let mut report = FdReport::new(&format!("{arch} kl_grads"));
    for l in 0..layers.len() {
        let r = layers[l].rank();
        // K-step: reparameterize layer l as W = K Vᵀ (u := K, s := I)
        let k0 = layers[l].k();
        for i in 0..k0.rows() {
            for j in 0..r {
                let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                    let mut k = k0.clone();
                    k[(i, j)] += e;
                    ls[l] = LowRankFactors {
                        u: k,
                        s: Matrix::eye(r, r),
                        v: ls[l].v.clone(),
                        bias: ls[l].bias.clone(),
                    };
                });
                report.check(kl.dk[l][(i, j)], numeric, &format!("dK[{l}][{i},{j}]"));
            }
        }
        // L-step: reparameterize layer l as W = U Lᵀ (v := L, s := I)
        let l0 = layers[l].l();
        for i in 0..l0.rows() {
            for j in 0..r {
                let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                    let mut lm = l0.clone();
                    lm[(i, j)] += e;
                    ls[l] = LowRankFactors {
                        u: ls[l].u.clone(),
                        s: Matrix::eye(r, r),
                        v: lm,
                        bias: ls[l].bias.clone(),
                    };
                });
                report.check(kl.dl[l][(i, j)], numeric, &format!("dL[{l}][{i},{j}]"));
            }
        }
    }
    report.finish(max_outliers);
}

/// FD-check every ∂S and ∂bias entry of `s_grads` against the `forward` loss.
fn check_s_finite_differences(
    be: &NativeBackend,
    arch: &str,
    layers: &[LowRankFactors],
    batch: &Batch,
    eps: f32,
    max_outliers: usize,
) {
    let sg = be.s_grads(arch, &refs(layers), batch).unwrap();
    let mut report = FdReport::new(&format!("{arch} s_grads"));
    for l in 0..layers.len() {
        let r = layers[l].rank();
        for i in 0..r {
            for j in 0..r {
                let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                    ls[l].s[(i, j)] += e;
                });
                report.check(sg.ds[l][(i, j)], numeric, &format!("dS[{l}][{i},{j}]"));
            }
        }
        for i in 0..layers[l].m() {
            let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                ls[l].bias[i] += e;
            });
            report.check(sg.db[l][i], numeric, &format!("db[{l}][{i}]"));
        }
    }
    report.finish(max_outliers);
}

#[test]
fn kl_grads_match_finite_differences() {
    let be = backend();
    let layers = tiny_layers(11);
    let batch = tiny_batch(12);
    check_kl_finite_differences(&be, ARCH, &layers, &batch, 1e-2, 0);
}

#[test]
fn s_grads_match_finite_differences() {
    let be = backend();
    let layers = tiny_layers(21);
    let batch = tiny_batch(22);
    check_s_finite_differences(&be, ARCH, &layers, &batch, 1e-2, 0);
}

#[test]
fn conv_kl_grads_match_finite_differences() {
    // the im2col + max-pool path: ∂K/∂L through patch contractions,
    // argmax routing and the ReLU mask. Small eps + an outlier allowance
    // of 2: central differences are invalid exactly at pool-argmax ties /
    // ReLU crossings (see FdReport), and only the conv layer's 24 entries
    // carry that risk.
    let be = backend();
    let layers = conv_layers(51);
    let batch = tiny_batch_dim(49, 52);
    check_kl_finite_differences(&be, CONV_ARCH, &layers, &batch, 1e-3, 2);
}

#[test]
fn conv_s_grads_match_finite_differences() {
    let be = backend();
    let layers = conv_layers(61);
    let batch = tiny_batch_dim(49, 62);
    check_s_finite_differences(&be, CONV_ARCH, &layers, &batch, 1e-3, 2);
}

#[test]
fn conv_factored_forward_matches_dense_reconstruction() {
    // the conv forward through U S Vᵀ equals the same conv with the
    // reconstructed full kernel matrix
    let be = backend();
    let layers = conv_layers(71);
    let batch = tiny_batch_dim(49, 72);
    let low = be.forward(CONV_ARCH, &refs(&layers), &batch).unwrap();
    let ws: Vec<Matrix> = layers.iter().map(|f| f.reconstruct()).collect();
    let bs: Vec<Vec<f32>> = layers.iter().map(|f| f.bias.clone()).collect();
    let dense = be.dense_forward(CONV_ARCH, &ws, &bs, &batch).unwrap();
    assert!(
        (low.loss - dense.loss).abs() < 1e-4,
        "conv factored vs dense forward: {} vs {}",
        low.loss,
        dense.loss
    );
    assert_eq!(low.ncorrect, dense.ncorrect);
}

#[test]
fn dense_grads_match_finite_differences_spot_check() {
    let be = backend();
    let mut rng = Rng::new(31);
    let ws = vec![rng.normal_matrix(7, DIM), rng.normal_matrix(CLASSES, 7)];
    let bs = vec![vec![0.1; 7], vec![-0.1; CLASSES]];
    let batch = tiny_batch(32);
    let grads = be.dense_grads(ARCH, &ws, &bs, &batch).unwrap();
    let eps = 1e-2;
    for (l, w) in ws.iter().enumerate() {
        for &(i, j) in &[(0usize, 0usize), (1, 2), (w.rows() - 1, w.cols() - 1)] {
            let mut plus = ws.clone();
            plus[l][(i, j)] += eps;
            let mut minus = ws.clone();
            minus[l][(i, j)] -= eps;
            let fp = be.dense_forward(ARCH, &plus, &bs, &batch).unwrap().loss;
            let fm = be.dense_forward(ARCH, &minus, &bs, &batch).unwrap().loss;
            let numeric = (fp - fm) / (2.0 * eps);
            assert_close(grads.dw[l][(i, j)], numeric, &format!("dW[{l}][{i},{j}]"));
        }
    }
}

#[test]
fn kl_and_s_gradients_are_consistent_projections() {
    // ∂S = Uᵀ ∂W V while ∂K = ∂W V: therefore Uᵀ ∂K must equal ∂S.
    // Checked on both the dense and the conv path.
    let be = backend();
    for (arch, layers, batch) in [
        (ARCH, tiny_layers(41), tiny_batch(42)),
        (CONV_ARCH, conv_layers(43), tiny_batch_dim(49, 44)),
    ] {
        let kl = be.kl_grads(arch, &refs(&layers), &batch).unwrap();
        let sg = be.s_grads(arch, &refs(&layers), &batch).unwrap();
        for (l, f) in layers.iter().enumerate() {
            let proj = dlrt::linalg::matmul_tn(&f.u, &kl.dk[l]);
            assert!(
                proj.fro_dist(&sg.ds[l]) < 1e-4,
                "{arch} layer {l}: Uᵀ∂K != ∂S ({})",
                proj.fro_dist(&sg.ds[l])
            );
        }
    }
}

#[test]
fn native_presets_resolve_their_archs() {
    // a preset pointing at an arch the native registry can't serve (the
    // old lenet/"jnp" split) must be impossible to reintroduce silently
    let be = NativeBackend::new();
    for (name, cfg) in presets::all() {
        if cfg.backend == "native" {
            be.arch(&cfg.arch)
                .unwrap_or_else(|e| panic!("preset {name} (arch {}): {e}", cfg.arch));
            assert!(be.batch_cap(&cfg.arch).unwrap() > 0, "preset {name}");
        }
    }
}

#[test]
fn adaptive_training_two_epoch_smoke_on_toy() {
    // The acceptance run: ModelState::Kls end-to-end on the native backend.
    let mut cfg = presets::quickstart();
    assert_eq!(cfg.backend, "native");
    cfg.epochs = 2;
    cfg.tau = 0.2;
    cfg.data = DataSource::Toy { n: 1_200 };
    let mut t = Trainer::new(cfg).unwrap();
    let rec = t.run("native_smoke", |_| {}).unwrap();
    assert!(matches!(t.model, ModelState::Kls(_)));
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    // init rank 16 on the two wide (32-max-rank) layers; adaptation must
    // have truncated at least one of them below that
    assert!(
        rec.final_ranks.iter().take(2).any(|&r| r < 16),
        "no layer truncated below init rank 16: {:?}",
        rec.final_ranks
    );
    // pinned classifier head stays at full rank 10
    assert_eq!(*rec.final_ranks.last().unwrap(), 10);
    assert!(rec.test_acc > 0.5, "toy task should be learnable (acc {})", rec.test_acc);
}

#[test]
fn lenet_adaptive_smoke_decreases_loss_and_truncates() {
    // the conv acceptance run: a tiny-budget rank-adaptive LeNet5 pass on
    // the hermetic native path (synthetic MNIST) must descend and truncate
    let mut cfg = presets::tab1_lenet(0.3);
    assert_eq!(cfg.backend, "native", "tab1 presets run natively now");
    cfg.epochs = 3;
    cfg.max_steps_per_epoch = 2;
    cfg.init_rank = 20;
    cfg.data = DataSource::Mnist { root: "data/mnist-absent".into(), n_synth: 1_500 };
    let mut t = Trainer::new(cfg).unwrap();
    let rec = t.run("lenet_native_smoke", |_| {}).unwrap();
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "LeNet loss did not decrease: {first} -> {last}");
    // layers: conv(20x25), conv(50x500), fc(500x800), head (pinned at 10)
    assert_eq!(rec.final_ranks.len(), 4);
    assert_eq!(*rec.final_ranks.last().unwrap(), 10, "head stays pinned");
    assert!(
        rec.final_ranks.iter().take(3).any(|&r| r < 20),
        "no layer truncated below init rank 20: {:?}",
        rec.final_ranks
    );
    // the paper's accounting applies (conv = compact convention)
    assert!(rec.eval_params > 0 && rec.eval_params < rec.dense_params);
}
