//! Native-backend correctness.
//!
//! * Finite-difference gradient checks of the `kl_grads` / `s_grads`
//!   services on a small custom architecture: the analytic `∂K`, `∂L`,
//!   `∂S`, `∂bias` (and a dense `∂W` spot check) must match central
//!   differences of the `forward` loss entry by entry.
//! * An end-to-end smoke: 2 epochs of rank-adaptive training on toy data
//!   through `ModelState::Kls` must decrease the loss and truncate at least
//!   one wide layer below its initial rank — the Algorithm 1 loop running
//!   entirely on the hermetic pure-Rust path.

use dlrt::backend::{ComputeBackend, LayerFactors, NativeBackend};
use dlrt::config::{presets, DataSource};
use dlrt::coordinator::{ModelState, Trainer};
use dlrt::data::Batch;
use dlrt::dlrt::LowRankFactors;
use dlrt::linalg::{Matrix, Rng};
use dlrt::runtime::{ArchInfo, LayerInfo};

const ARCH: &str = "fd_tiny";
const DIM: usize = 9;
const CLASSES: usize = 5;
const BATCH: usize = 8;

fn dense_layer(m: usize, n: usize) -> LayerInfo {
    LayerInfo {
        kind: "dense".into(),
        m,
        n,
        in_ch: 0,
        out_ch: 0,
        ksize: 0,
        in_h: 0,
        in_w: 0,
        pool: false,
        out_h: 0,
        out_w: 0,
    }
}

fn backend() -> NativeBackend {
    let arch = ArchInfo {
        layers: vec![dense_layer(7, DIM), dense_layer(CLASSES, 7)],
        input_dim: DIM,
        num_classes: CLASSES,
        image_hwc: None,
    };
    NativeBackend::new().with_arch(ARCH, arch, BATCH)
}

fn tiny_batch(seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    Batch {
        x: (0..BATCH * DIM).map(|_| rng.normal()).collect(),
        y: (0..BATCH).map(|_| rng.below(CLASSES) as i32).collect(),
        w: vec![1.0; BATCH],
        count: BATCH,
    }
}

fn tiny_layers(seed: u64) -> Vec<LowRankFactors> {
    let mut rng = Rng::new(seed);
    vec![LowRankFactors::random(7, DIM, 3, &mut rng), LowRankFactors::random(CLASSES, 7, 4, &mut rng)]
}

fn refs(layers: &[LowRankFactors]) -> Vec<LayerFactors<'_>> {
    layers
        .iter()
        .map(|f| LayerFactors { u: &f.u, s: &f.s, v: &f.v, bias: &f.bias })
        .collect()
}

fn loss_of(be: &NativeBackend, layers: &[LowRankFactors], batch: &Batch) -> f32 {
    be.forward(ARCH, &refs(layers), batch).unwrap().loss
}

/// Central difference of `loss` along one entry of a factor, selected and
/// perturbed by `apply`.
fn central_diff(
    be: &NativeBackend,
    layers: &[LowRankFactors],
    batch: &Batch,
    eps: f32,
    apply: impl Fn(&mut Vec<LowRankFactors>, f32),
) -> f32 {
    let mut plus = layers.to_vec();
    apply(&mut plus, eps);
    let mut minus = layers.to_vec();
    apply(&mut minus, -eps);
    (loss_of(be, &plus, batch) - loss_of(be, &minus, batch)) / (2.0 * eps)
}

fn assert_close(analytic: f32, numeric: f32, what: &str) {
    let tol = 2e-3 + 2e-2 * numeric.abs();
    assert!(
        (analytic - numeric).abs() <= tol,
        "{what}: analytic {analytic} vs finite-difference {numeric}"
    );
}

#[test]
fn kl_grads_match_finite_differences() {
    let be = backend();
    let layers = tiny_layers(11);
    let batch = tiny_batch(12);
    let kl = be.kl_grads(ARCH, &refs(&layers), &batch).unwrap();
    let eps = 1e-2;
    for l in 0..layers.len() {
        let r = layers[l].rank();
        // K-step: reparameterize layer l as W = K Vᵀ (u := K, s := I)
        let k0 = layers[l].k();
        for i in 0..k0.rows() {
            for j in 0..r {
                let numeric = central_diff(&be, &layers, &batch, eps, |ls, e| {
                    let mut k = k0.clone();
                    k[(i, j)] += e;
                    ls[l] = LowRankFactors {
                        u: k,
                        s: Matrix::eye(r, r),
                        v: ls[l].v.clone(),
                        bias: ls[l].bias.clone(),
                    };
                });
                assert_close(kl.dk[l][(i, j)], numeric, &format!("dK[{l}][{i},{j}]"));
            }
        }
        // L-step: reparameterize layer l as W = U Lᵀ (v := L, s := I)
        let l0 = layers[l].l();
        for i in 0..l0.rows() {
            for j in 0..r {
                let numeric = central_diff(&be, &layers, &batch, eps, |ls, e| {
                    let mut lm = l0.clone();
                    lm[(i, j)] += e;
                    ls[l] = LowRankFactors {
                        u: ls[l].u.clone(),
                        s: Matrix::eye(r, r),
                        v: lm,
                        bias: ls[l].bias.clone(),
                    };
                });
                assert_close(kl.dl[l][(i, j)], numeric, &format!("dL[{l}][{i},{j}]"));
            }
        }
    }
}

#[test]
fn s_grads_match_finite_differences() {
    let be = backend();
    let layers = tiny_layers(21);
    let batch = tiny_batch(22);
    let sg = be.s_grads(ARCH, &refs(&layers), &batch).unwrap();
    let eps = 1e-2;
    for l in 0..layers.len() {
        let r = layers[l].rank();
        for i in 0..r {
            for j in 0..r {
                let numeric = central_diff(&be, &layers, &batch, eps, |ls, e| {
                    ls[l].s[(i, j)] += e;
                });
                assert_close(sg.ds[l][(i, j)], numeric, &format!("dS[{l}][{i},{j}]"));
            }
        }
        for i in 0..layers[l].m() {
            let numeric = central_diff(&be, &layers, &batch, eps, |ls, e| {
                ls[l].bias[i] += e;
            });
            assert_close(sg.db[l][i], numeric, &format!("db[{l}][{i}]"));
        }
    }
}

#[test]
fn dense_grads_match_finite_differences_spot_check() {
    let be = backend();
    let mut rng = Rng::new(31);
    let ws = vec![rng.normal_matrix(7, DIM), rng.normal_matrix(CLASSES, 7)];
    let bs = vec![vec![0.1; 7], vec![-0.1; CLASSES]];
    let batch = tiny_batch(32);
    let grads = be.dense_grads(ARCH, &ws, &bs, &batch).unwrap();
    let eps = 1e-2;
    for (l, w) in ws.iter().enumerate() {
        for &(i, j) in &[(0usize, 0usize), (1, 2), (w.rows() - 1, w.cols() - 1)] {
            let mut plus = ws.clone();
            plus[l][(i, j)] += eps;
            let mut minus = ws.clone();
            minus[l][(i, j)] -= eps;
            let fp = be.dense_forward(ARCH, &plus, &bs, &batch).unwrap().loss;
            let fm = be.dense_forward(ARCH, &minus, &bs, &batch).unwrap().loss;
            let numeric = (fp - fm) / (2.0 * eps);
            assert_close(grads.dw[l][(i, j)], numeric, &format!("dW[{l}][{i},{j}]"));
        }
    }
}

#[test]
fn kl_and_s_gradients_are_consistent_projections() {
    // ∂S = Uᵀ ∂W V while ∂K = ∂W V: therefore Uᵀ ∂K must equal ∂S.
    let be = backend();
    let layers = tiny_layers(41);
    let batch = tiny_batch(42);
    let kl = be.kl_grads(ARCH, &refs(&layers), &batch).unwrap();
    let sg = be.s_grads(ARCH, &refs(&layers), &batch).unwrap();
    for (l, f) in layers.iter().enumerate() {
        let proj = dlrt::linalg::matmul_tn(&f.u, &kl.dk[l]);
        assert!(
            proj.fro_dist(&sg.ds[l]) < 1e-4,
            "layer {l}: Uᵀ∂K != ∂S ({})",
            proj.fro_dist(&sg.ds[l])
        );
    }
}

#[test]
fn adaptive_training_two_epoch_smoke_on_toy() {
    // The acceptance run: ModelState::Kls end-to-end on the native backend.
    let mut cfg = presets::quickstart();
    assert_eq!(cfg.backend, "native");
    cfg.epochs = 2;
    cfg.tau = 0.2;
    cfg.data = DataSource::Toy { n: 1_200 };
    let mut t = Trainer::new(cfg).unwrap();
    let rec = t.run("native_smoke", |_| {}).unwrap();
    assert!(matches!(t.model, ModelState::Kls(_)));
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    // init rank 16 on the two wide (32-max-rank) layers; adaptation must
    // have truncated at least one of them below that
    assert!(
        rec.final_ranks.iter().take(2).any(|&r| r < 16),
        "no layer truncated below init rank 16: {:?}",
        rec.final_ranks
    );
    // pinned classifier head stays at full rank 10
    assert_eq!(*rec.final_ranks.last().unwrap(), 10);
    assert!(rec.test_acc > 0.5, "toy task should be learnable (acc {})", rec.test_acc);
}
