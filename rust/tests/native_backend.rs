//! Native-backend correctness.
//!
//! * Finite-difference gradient checks of the two-call `grads` service on
//!   small custom architectures — one fully-connected, one convolutional
//!   (im2col + max-pool path), one *mixed* (dense layer + factored layer
//!   in the same sweep) — the analytic `∂K`, `∂L`, `∂S`, `∂bias` (and
//!   dense `∂W` spot checks) must match central differences of the
//!   `forward` loss entry by entry.
//! * End-to-end smokes: rank-adaptive training through the unified
//!   `Network` must decrease the loss and truncate ranks below init, on
//!   toy data (MLP), on LeNet5 (conv), and on the TRP-style mixed
//!   dense-conv-prefix + low-rank-tail LeNet — Algorithm 1's scheduler
//!   running entirely on the hermetic pure-Rust path.
//! * Preset/registry consistency: every preset that declares
//!   `backend = "native"` must resolve its architecture in the native
//!   registry, so a preset/registry drift cannot silently recur.

use dlrt::backend::{ComputeBackend, GradPhase, GradsOut, LayerGrads, LayerParams, NativeBackend};
use dlrt::config::{presets, DataSource};
use dlrt::coordinator::Trainer;
use dlrt::data::Batch;
use dlrt::dlrt::LowRankFactors;
use dlrt::linalg::{Matrix, Rng};
use dlrt::runtime::{ArchInfo, LayerInfo};

const ARCH: &str = "fd_tiny";
const DIM: usize = 9;
const CLASSES: usize = 5;
const BATCH: usize = 8;

fn dense_layer(m: usize, n: usize) -> LayerInfo {
    LayerInfo {
        kind: "dense".into(),
        m,
        n,
        in_ch: 0,
        out_ch: 0,
        ksize: 0,
        in_h: 0,
        in_w: 0,
        pool: false,
        out_h: 0,
        out_w: 0,
    }
}

fn conv_layer(
    in_ch: usize,
    out_ch: usize,
    ksize: usize,
    in_h: usize,
    in_w: usize,
    pool: bool,
) -> LayerInfo {
    let (hp, wp) = (in_h - ksize + 1, in_w - ksize + 1);
    let (out_h, out_w) = if pool { (hp / 2, wp / 2) } else { (hp, wp) };
    LayerInfo {
        kind: "conv".into(),
        m: out_ch,
        n: in_ch * ksize * ksize,
        in_ch,
        out_ch,
        ksize,
        in_h,
        in_w,
        pool,
        out_h,
        out_w,
    }
}

fn backend() -> NativeBackend {
    let dense_arch = ArchInfo {
        layers: vec![dense_layer(7, DIM), dense_layer(CLASSES, 7)],
        input_dim: DIM,
        num_classes: CLASSES,
        image_hwc: None,
    };
    // conv FD net: 7x7x1 -> conv(1->3, k3) 5x5x3 -> pool 2x2x3 = 12 -> head
    let conv_arch = ArchInfo {
        layers: vec![conv_layer(1, 3, 3, 7, 7, true), dense_layer(CLASSES, 12)],
        input_dim: 49,
        num_classes: CLASSES,
        image_hwc: Some([7, 7, 1]),
    };
    NativeBackend::new()
        .with_arch(ARCH, dense_arch, BATCH)
        .with_arch(CONV_ARCH, conv_arch, BATCH)
}

const CONV_ARCH: &str = "fd_conv";

fn tiny_batch_dim(dim: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    Batch {
        x: (0..BATCH * dim).map(|_| rng.normal()).collect(),
        y: (0..BATCH).map(|_| rng.below(CLASSES) as i32).collect(),
        w: vec![1.0; BATCH],
        count: BATCH,
    }
}

fn tiny_batch(seed: u64) -> Batch {
    tiny_batch_dim(DIM, seed)
}

fn tiny_layers(seed: u64) -> Vec<LowRankFactors> {
    let mut rng = Rng::new(seed);
    vec![
        LowRankFactors::random(7, DIM, 3, &mut rng),
        LowRankFactors::random(CLASSES, 7, 4, &mut rng),
    ]
}

fn conv_layers(seed: u64) -> Vec<LowRankFactors> {
    let mut rng = Rng::new(seed);
    vec![
        LowRankFactors::random(3, 9, 2, &mut rng),
        LowRankFactors::random(CLASSES, 12, 4, &mut rng),
    ]
}

fn refs(layers: &[LowRankFactors]) -> Vec<LayerParams<'_>> {
    layers
        .iter()
        .map(|f| LayerParams::Factored { u: &f.u, s: &f.s, v: &f.v, bias: &f.bias })
        .collect()
}

/// Per-layer ∂K/∂L of a Kl-phase grads call over an all-factored net.
fn kl_of(out: GradsOut) -> (Vec<Matrix>, Vec<Matrix>) {
    let mut dk = Vec::new();
    let mut dl = Vec::new();
    for g in out.layers {
        match g {
            LayerGrads::Kl { dk: a, dl: b } => {
                dk.push(a);
                dl.push(b);
            }
            _ => panic!("expected Kl grads for every factored layer"),
        }
    }
    (dk, dl)
}

/// Per-layer ∂S/∂b of an S-phase grads call over an all-factored net.
fn s_of(out: GradsOut) -> (Vec<Matrix>, Vec<Vec<f32>>) {
    let mut ds = Vec::new();
    let mut db = Vec::new();
    for g in out.layers {
        match g {
            LayerGrads::S { ds: a, db: b } => {
                ds.push(a);
                db.push(b);
            }
            _ => panic!("expected S grads for every factored layer"),
        }
    }
    (ds, db)
}

fn loss_of(be: &NativeBackend, arch: &str, layers: &[LowRankFactors], batch: &Batch) -> f32 {
    be.forward(arch, &refs(layers), batch).unwrap().loss
}

/// Central difference of `loss` along one entry of a factor, selected and
/// perturbed by `apply`.
fn central_diff(
    be: &NativeBackend,
    arch: &str,
    layers: &[LowRankFactors],
    batch: &Batch,
    eps: f32,
    apply: impl Fn(&mut Vec<LowRankFactors>, f32),
) -> f32 {
    let mut plus = layers.to_vec();
    apply(&mut plus, eps);
    let mut minus = layers.to_vec();
    apply(&mut minus, -eps);
    (loss_of(be, arch, &plus, batch) - loss_of(be, arch, &minus, batch)) / (2.0 * eps)
}

fn assert_close(analytic: f32, numeric: f32, what: &str) {
    let tol = 2e-3 + 2e-2 * numeric.abs();
    assert!(
        (analytic - numeric).abs() <= tol,
        "{what}: analytic {analytic} vs finite-difference {numeric}"
    );
}

/// Collects per-entry (analytic, numeric) pairs of one FD sweep.
///
/// `max_outliers = 0` demands every entry match. The conv checks pass a
/// small allowance instead: central differences are one-sided near a
/// max-pool argmax tie or a ReLU zero crossing, so an *isolated* entry may
/// legitimately disagree; a real gradient bug (wrong patch/pool index
/// mapping) corrupts entries wholesale and still fails the cap.
struct FdReport {
    what: String,
    checked: usize,
    failures: Vec<String>,
}

impl FdReport {
    fn new(what: &str) -> FdReport {
        FdReport { what: what.into(), checked: 0, failures: Vec::new() }
    }

    fn check(&mut self, analytic: f32, numeric: f32, entry: &str) {
        self.checked += 1;
        let tol = 2e-3 + 2e-2 * numeric.abs();
        if (analytic - numeric).abs() > tol {
            self.failures.push(format!("{entry}: analytic {analytic} vs fd {numeric}"));
        }
    }

    fn finish(self, max_outliers: usize) {
        assert!(
            self.failures.len() <= max_outliers,
            "{}: {}/{} entries off (allowed {}):\n{}",
            self.what,
            self.failures.len(),
            self.checked,
            max_outliers,
            self.failures.join("\n")
        );
    }
}

/// FD-check every ∂K and ∂L entry of the Kl phase against the `forward`
/// loss.
fn check_kl_finite_differences(
    be: &NativeBackend,
    arch: &str,
    layers: &[LowRankFactors],
    batch: &Batch,
    eps: f32,
    max_outliers: usize,
) {
    let (dk_all, dl_all) = kl_of(be.grads(arch, &refs(layers), GradPhase::Kl, batch).unwrap());
    let mut report = FdReport::new(&format!("{arch} grads/kl"));
    for l in 0..layers.len() {
        let r = layers[l].rank();
        // K-step: reparameterize layer l as W = K Vᵀ (u := K, s := I)
        let k0 = layers[l].k();
        for i in 0..k0.rows() {
            for j in 0..r {
                let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                    let mut k = k0.clone();
                    k[(i, j)] += e;
                    ls[l] = LowRankFactors {
                        u: k,
                        s: Matrix::eye(r, r),
                        v: ls[l].v.clone(),
                        bias: ls[l].bias.clone(),
                    };
                });
                report.check(dk_all[l][(i, j)], numeric, &format!("dK[{l}][{i},{j}]"));
            }
        }
        // L-step: reparameterize layer l as W = U Lᵀ (v := L, s := I)
        let l0 = layers[l].l();
        for i in 0..l0.rows() {
            for j in 0..r {
                let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                    let mut lm = l0.clone();
                    lm[(i, j)] += e;
                    ls[l] = LowRankFactors {
                        u: ls[l].u.clone(),
                        s: Matrix::eye(r, r),
                        v: lm,
                        bias: ls[l].bias.clone(),
                    };
                });
                report.check(dl_all[l][(i, j)], numeric, &format!("dL[{l}][{i},{j}]"));
            }
        }
    }
    report.finish(max_outliers);
}

/// FD-check every ∂S and ∂bias entry of the S phase against the `forward`
/// loss.
fn check_s_finite_differences(
    be: &NativeBackend,
    arch: &str,
    layers: &[LowRankFactors],
    batch: &Batch,
    eps: f32,
    max_outliers: usize,
) {
    let (ds_all, db_all) = s_of(be.grads(arch, &refs(layers), GradPhase::S, batch).unwrap());
    let mut report = FdReport::new(&format!("{arch} grads/s"));
    for l in 0..layers.len() {
        let r = layers[l].rank();
        for i in 0..r {
            for j in 0..r {
                let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                    ls[l].s[(i, j)] += e;
                });
                report.check(ds_all[l][(i, j)], numeric, &format!("dS[{l}][{i},{j}]"));
            }
        }
        for i in 0..layers[l].m() {
            let numeric = central_diff(be, arch, layers, batch, eps, |ls, e| {
                ls[l].bias[i] += e;
            });
            report.check(db_all[l][i], numeric, &format!("db[{l}][{i}]"));
        }
    }
    report.finish(max_outliers);
}

#[test]
fn kl_grads_match_finite_differences() {
    let be = backend();
    let layers = tiny_layers(11);
    let batch = tiny_batch(12);
    check_kl_finite_differences(&be, ARCH, &layers, &batch, 1e-2, 0);
}

#[test]
fn s_grads_match_finite_differences() {
    let be = backend();
    let layers = tiny_layers(21);
    let batch = tiny_batch(22);
    check_s_finite_differences(&be, ARCH, &layers, &batch, 1e-2, 0);
}

#[test]
fn conv_kl_grads_match_finite_differences() {
    // the im2col + max-pool path: ∂K/∂L through patch contractions,
    // argmax routing and the ReLU mask. Small eps + an outlier allowance
    // of 2: central differences are invalid exactly at pool-argmax ties /
    // ReLU crossings (see FdReport), and only the conv layer's 24 entries
    // carry that risk.
    let be = backend();
    let layers = conv_layers(51);
    let batch = tiny_batch_dim(49, 52);
    check_kl_finite_differences(&be, CONV_ARCH, &layers, &batch, 1e-3, 2);
}

#[test]
fn conv_s_grads_match_finite_differences() {
    let be = backend();
    let layers = conv_layers(61);
    let batch = tiny_batch_dim(49, 62);
    check_s_finite_differences(&be, CONV_ARCH, &layers, &batch, 1e-3, 2);
}

#[test]
fn conv_factored_forward_matches_dense_reconstruction() {
    // the conv forward through U S Vᵀ equals the same conv with the
    // reconstructed full kernel matrix
    let be = backend();
    let layers = conv_layers(71);
    let batch = tiny_batch_dim(49, 72);
    let low = be.forward(CONV_ARCH, &refs(&layers), &batch).unwrap();
    let ws: Vec<Matrix> = layers.iter().map(|f| f.reconstruct()).collect();
    let dense_params: Vec<LayerParams<'_>> = ws
        .iter()
        .zip(&layers)
        .map(|(w, f)| LayerParams::Dense { w, bias: &f.bias })
        .collect();
    let dense = be.forward(CONV_ARCH, &dense_params, &batch).unwrap();
    assert!(
        (low.loss - dense.loss).abs() < 1e-4,
        "conv factored vs dense forward: {} vs {}",
        low.loss,
        dense.loss
    );
    assert_eq!(low.ncorrect, dense.ncorrect);
}

#[test]
fn dense_grads_match_finite_differences_spot_check() {
    let be = backend();
    let mut rng = Rng::new(31);
    let ws = vec![rng.normal_matrix(7, DIM), rng.normal_matrix(CLASSES, 7)];
    let bs = vec![vec![0.1; 7], vec![-0.1; CLASSES]];
    let batch = tiny_batch(32);
    let params: Vec<LayerParams<'_>> = ws
        .iter()
        .zip(&bs)
        .map(|(w, b)| LayerParams::Dense { w, bias: b })
        .collect();
    let out = be.grads(ARCH, &params, GradPhase::Kl, &batch).unwrap();
    let dense_loss = |ws: &[Matrix]| {
        let params: Vec<LayerParams<'_>> = ws
            .iter()
            .zip(&bs)
            .map(|(w, b)| LayerParams::Dense { w, bias: b })
            .collect();
        be.forward(ARCH, &params, &batch).unwrap().loss
    };
    let eps = 1e-2;
    for (l, w) in ws.iter().enumerate() {
        let dw = match &out.layers[l] {
            LayerGrads::Dense { dw, .. } => dw,
            _ => panic!("expected dense grads"),
        };
        for &(i, j) in &[(0usize, 0usize), (1, 2), (w.rows() - 1, w.cols() - 1)] {
            let mut plus = ws.clone();
            plus[l][(i, j)] += eps;
            let mut minus = ws.clone();
            minus[l][(i, j)] -= eps;
            let numeric = (dense_loss(&plus) - dense_loss(&minus)) / (2.0 * eps);
            assert_close(dw[(i, j)], numeric, &format!("dW[{l}][{i},{j}]"));
        }
    }
}

#[test]
fn mixed_net_grads_match_finite_differences() {
    // dense layer 0 + factored layer 1 in ONE sweep: both layers' analytic
    // gradients must match finite differences of the mixed forward — the
    // correctness core of the TRP-style dense-prefix + low-rank-tail nets
    let be = backend();
    let layers = tiny_layers(81);
    let w0 = layers[0].reconstruct();
    let b0 = layers[0].bias.clone();
    let f1 = &layers[1];
    let r = f1.rank();
    let batch = tiny_batch(82);
    let params = vec![
        LayerParams::Dense { w: &w0, bias: &b0 },
        LayerParams::Factored { u: &f1.u, s: &f1.s, v: &f1.v, bias: &f1.bias },
    ];
    let out = be.grads(ARCH, &params, GradPhase::Kl, &batch).unwrap();
    let dw = match &out.layers[0] {
        LayerGrads::Dense { dw, .. } => dw,
        _ => panic!("expected dense grads for layer 0"),
    };
    let dk = match &out.layers[1] {
        LayerGrads::Kl { dk, .. } => dk,
        _ => panic!("expected Kl grads for layer 1"),
    };
    let loss_with = |w0p: &Matrix, f1p: &LowRankFactors| {
        let params = vec![
            LayerParams::Dense { w: w0p, bias: &b0 },
            LayerParams::Factored { u: &f1p.u, s: &f1p.s, v: &f1p.v, bias: &f1p.bias },
        ];
        be.forward(ARCH, &params, &batch).unwrap().loss
    };
    let eps = 1e-2;
    // dense entries
    for &(i, j) in &[(0usize, 0usize), (3, 4), (6, 8)] {
        let mut plus = w0.clone();
        plus[(i, j)] += eps;
        let mut minus = w0.clone();
        minus[(i, j)] -= eps;
        let numeric = (loss_with(&plus, f1) - loss_with(&minus, f1)) / (2.0 * eps);
        assert_close(dw[(i, j)], numeric, &format!("mixed dW[{i},{j}]"));
    }
    // K entries of the factored layer: perturb K with S := I
    let k0 = f1.k();
    for &(i, j) in &[(0usize, 0usize), (2, 1), (4, 3)] {
        let perturbed = |e: f32| LowRankFactors {
            u: {
                let mut k = k0.clone();
                k[(i, j)] += e;
                k
            },
            s: Matrix::eye(r, r),
            v: f1.v.clone(),
            bias: f1.bias.clone(),
        };
        let numeric = (loss_with(&w0, &perturbed(eps)) - loss_with(&w0, &perturbed(-eps)))
            / (2.0 * eps);
        assert_close(dk[(i, j)], numeric, &format!("mixed dK[{i},{j}]"));
    }
    // the S phase of the same mixed net only grads the factored layer
    let s = be.grads(ARCH, &params, GradPhase::S, &batch).unwrap();
    assert!(matches!(s.layers[0], LayerGrads::None));
    assert!(matches!(s.layers[1], LayerGrads::S { .. }));
}

#[test]
fn kl_and_s_gradients_are_consistent_projections() {
    // ∂S = Uᵀ ∂W V while ∂K = ∂W V: therefore Uᵀ ∂K must equal ∂S.
    // Checked on both the dense and the conv path.
    let be = backend();
    for (arch, layers, batch) in [
        (ARCH, tiny_layers(41), tiny_batch(42)),
        (CONV_ARCH, conv_layers(43), tiny_batch_dim(49, 44)),
    ] {
        let (dk, _) = kl_of(be.grads(arch, &refs(&layers), GradPhase::Kl, &batch).unwrap());
        let (ds, _) = s_of(be.grads(arch, &refs(&layers), GradPhase::S, &batch).unwrap());
        for (l, f) in layers.iter().enumerate() {
            let proj = dlrt::linalg::matmul_tn(&f.u, &dk[l]);
            assert!(
                proj.fro_dist(&ds[l]) < 1e-4,
                "{arch} layer {l}: Uᵀ∂K != ∂S ({})",
                proj.fro_dist(&ds[l])
            );
        }
    }
}

#[test]
fn native_presets_resolve_their_archs() {
    // a preset pointing at an arch the native registry can't serve (the
    // old lenet/"jnp" split) must be impossible to reintroduce silently
    let be = NativeBackend::new();
    for (name, cfg) in presets::all() {
        if cfg.backend == "native" {
            be.arch(&cfg.arch)
                .unwrap_or_else(|e| panic!("preset {name} (arch {}): {e}", cfg.arch));
            assert!(be.batch_cap(&cfg.arch).unwrap() > 0, "preset {name}");
        }
    }
}

#[test]
fn adaptive_training_two_epoch_smoke_on_toy() {
    // The acceptance run: the unified Network end-to-end on the native
    // backend, all layers adaptive DLRT.
    let mut cfg = presets::quickstart();
    assert_eq!(cfg.backend, "native");
    cfg.epochs = 2;
    cfg.tau = 0.2;
    cfg.data = DataSource::Toy { n: 1_200 };
    let mut t = Trainer::new(cfg).unwrap();
    let rec = t.run("native_smoke", |_| {}).unwrap();
    assert!(t.model.layers.iter().all(|l| l.is_factored()));
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    // init rank 16 on the two wide (32-max-rank) layers; adaptation must
    // have truncated at least one of them below that
    assert!(
        rec.final_ranks.iter().take(2).any(|&r| r < 16),
        "no layer truncated below init rank 16: {:?}",
        rec.final_ranks
    );
    // pinned classifier head stays at full rank 10
    assert_eq!(*rec.final_ranks.last().unwrap(), 10);
    assert!(rec.test_acc > 0.5, "toy task should be learnable (acc {})", rec.test_acc);
}

#[test]
fn lenet_adaptive_smoke_decreases_loss_and_truncates() {
    // the conv acceptance run: a tiny-budget rank-adaptive LeNet5 pass on
    // the hermetic native path (synthetic MNIST) must descend and truncate
    let mut cfg = presets::tab1_lenet(0.3);
    assert_eq!(cfg.backend, "native", "tab1 presets run natively now");
    cfg.epochs = 3;
    cfg.max_steps_per_epoch = 2;
    cfg.init_rank = 20;
    cfg.data = DataSource::Mnist { root: "data/mnist-absent".into(), n_synth: 1_500 };
    let mut t = Trainer::new(cfg).unwrap();
    let rec = t.run("lenet_native_smoke", |_| {}).unwrap();
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "LeNet loss did not decrease: {first} -> {last}");
    // layers: conv(20x25), conv(50x500), fc(500x800), head (pinned at 10)
    assert_eq!(rec.final_ranks.len(), 4);
    assert_eq!(*rec.final_ranks.last().unwrap(), 10, "head stays pinned");
    assert!(
        rec.final_ranks.iter().take(3).any(|&r| r < 20),
        "no layer truncated below init rank 20: {:?}",
        rec.final_ranks
    );
    // the paper's accounting applies (conv = compact convention)
    assert!(rec.eval_params > 0 && rec.eval_params < rec.dense_params);
}

#[test]
fn trp_mixed_lenet_smoke_trains_and_truncates() {
    // the tentpole proof: a TRP-style mixed net — dense conv prefix +
    // adaptive low-rank dense tail — trains end-to-end on the native
    // backend; inexpressible before the per-layer model core
    let mut cfg = presets::trp_lenet(0.3);
    assert_eq!(cfg.backend, "native");
    cfg.epochs = 3;
    cfg.max_steps_per_epoch = 2;
    cfg.init_rank = 20;
    cfg.data = DataSource::Mnist { root: "data/mnist-absent".into(), n_synth: 1_500 };
    let mut t = Trainer::new(cfg).unwrap();
    assert_eq!(t.model.layers[0].kind(), "dense");
    assert_eq!(t.model.layers[1].kind(), "dense");
    assert!(t.model.layers[2].is_factored() && t.model.layers[3].is_factored());
    let rec = t.run("trp_smoke", |_| {}).unwrap();
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "mixed TRP loss did not decrease: {first} -> {last}");
    // dense conv layers report their full rank; the adaptive fc tail
    // truncates below its init rank 20; the head stays pinned at 10
    assert_eq!(rec.final_ranks.len(), 4);
    assert_eq!(rec.final_ranks[0], 20, "dense conv1 is full-rank");
    assert_eq!(rec.final_ranks[1], 50, "dense conv2 is full-rank");
    assert!(
        rec.final_ranks[2] < 20,
        "low-rank tail did not truncate: {:?}",
        rec.final_ranks
    );
    assert_eq!(*rec.final_ranks.last().unwrap(), 10, "head stays pinned");
    // the S phase ran (factored layers present), so its wall clock is real
    assert!(rec.epochs[0].s_graph_seconds > 0.0);
}
