//! Theorem-level validation of the KLS integrator math (paper §4.1).
//!
//! These tests run the *host* side of Algorithm 1 (K/L/S Euler steps, QR
//! augmentation, SVD truncation — exactly the code `dlrt::dlrt` uses) on an
//! analytic matrix gradient flow where the exact solution is known:
//!
//!     L(W) = ½‖W − A‖²_F,   Ẇ = −(W − A),   W(t) = A + e^{−t}(W₀ − A).
//!
//! * **Theorem 1** (approximation): with A of exact rank r (ε = 0), the
//!   rank-adaptive iterate stays `O(η + ϑ/η)`-close to the exact flow, with
//!   constants independent of the singular values.
//! * **Theorem 2** (descent): the loss decreases monotonically up to `βϑ`.

use dlrt::linalg::{householder_qr, jacobi_svd, matmul, matmul_nt, matmul_tn, Matrix, Rng};

/// Exact-rank-`r` random target with prescribed singular values.
fn target(m: usize, n: usize, sigma: &[f32], rng: &mut Rng) -> Matrix {
    let r = sigma.len();
    let q1 = householder_qr(&rng.normal_matrix(m, r));
    let q2 = householder_qr(&rng.normal_matrix(n, r));
    let mut d = Matrix::zeros(r, r);
    for (i, &s) in sigma.iter().enumerate() {
        d[(i, i)] = s;
    }
    matmul(&matmul(&q1, &d), &q2.transpose())
}

struct Factors {
    u: Matrix,
    s: Matrix,
    v: Matrix,
}

fn random_factors(m: usize, n: usize, r: usize, rng: &mut Rng) -> Factors {
    let u = householder_qr(&rng.normal_matrix(m, r));
    let v = householder_qr(&rng.normal_matrix(n, r));
    let s = rng.normal_matrix(r, r);
    Factors { u, s, v }
}

fn reconstruct(f: &Factors) -> Matrix {
    matmul(&matmul(&f.u, &f.s), &f.v.transpose())
}

/// One KLS step (Alg. 1) for the analytic flow F(W) = −(W − A), explicit
/// Euler with step η; adaptive augmentation + τ-truncation when `adaptive`.
fn host_kls_step(f: &Factors, a: &Matrix, eta: f32, tau: f32, adaptive: bool) -> Factors {
    let r = f.s.rows();
    let (m, n) = (f.u.rows(), f.v.rows());
    // K-step: K1 = K0 − η (K0 V0ᵀ − A) V0
    let k0 = matmul(&f.u, &f.s);
    let w0 = matmul(&k0, &f.v.transpose());
    let mut gk = matmul(&w0, &f.v); // (W0) V0
    gk.axpy(-1.0, &matmul(a, &f.v)); // (W0 − A) V0
    let mut k1 = k0.clone();
    k1.axpy(-eta, &gk);
    // L-step: L1 = L0 − η (W0 − A)ᵀ U0
    let l0 = matmul(&f.v, &f.s.transpose());
    let mut diff = w0.clone();
    diff.axpy(-1.0, a);
    let gl = matmul_tn(&diff, &f.u); // (W0−A)ᵀ U0
    let mut l1 = l0.clone();
    l1.axpy(-eta, &gl);

    let (u1, v1) = if adaptive {
        let raug = (2 * r).min(m).min(n);
        (
            householder_qr(&k1.hcat(&f.u)).take_cols(raug),
            householder_qr(&l1.hcat(&f.v)).take_cols(raug),
        )
    } else {
        (householder_qr(&k1), householder_qr(&l1))
    };
    // S̃ = (U1ᵀU0) S0 (V0ᵀV1)
    let mk = matmul_tn(&u1, &f.u);
    let nk = matmul_tn(&v1, &f.v);
    let s_tilde = matmul(&matmul(&mk, &f.s), &nk.transpose());
    // S-step: S1 = S̃ − η (S̃ − U1ᵀ A V1)
    let proj_a = matmul(&matmul_tn(&u1, a), &v1);
    let mut s1 = s_tilde.clone();
    let mut ds = s_tilde;
    ds.axpy(-1.0, &proj_a);
    s1.axpy(-eta, &ds);

    if adaptive {
        let svd = jacobi_svd(&s1);
        let theta = tau * svd.sigma_fro();
        let r_new = svd.truncation_rank(theta, 2);
        let mut s_next = Matrix::zeros(r_new, r_new);
        for i in 0..r_new {
            s_next[(i, i)] = svd.sigma[i];
        }
        Factors {
            u: matmul(&u1, &svd.u.take_cols(r_new)),
            s: s_next,
            v: matmul(&v1, &svd.vt.transpose().take_cols(r_new)),
        }
    } else {
        Factors { u: u1, s: s1, v: v1 }
    }
}

/// Exact flow value at time t: A + e^{−t} (W0 − A).
fn exact_flow(a: &Matrix, w0: &Matrix, t: f32) -> Matrix {
    let mut w = w0.clone();
    w.axpy(-1.0, a);
    w.scale((-t).exp());
    w.axpy(1.0, a);
    w
}

fn loss(w: &Matrix, a: &Matrix) -> f32 {
    0.5 * w.fro_dist(a).powi(2)
}

#[test]
fn theorem1_error_is_first_order_in_eta() {
    // P2 (ε-closeness to the manifold) is satisfied by construction: A and
    // W0 share their rank-6 row/column subspaces, so the exact trajectory
    // W(t) = A + e^{−t}(W0 − A) stays on M_6 exactly (ε = 0) and Thm 1
    // predicts global error c2·η.
    let mut rng = Rng::new(42);
    let u0 = householder_qr(&rng.normal_matrix(24, 6));
    let v0 = householder_qr(&rng.normal_matrix(18, 6));
    let mut sa = Matrix::zeros(6, 6);
    for (i, s) in [5.0f32, 3.0, 1.0].into_iter().enumerate() {
        sa[(i, i)] = s;
    }
    let a = matmul(&matmul(&u0, &sa), &v0.transpose());
    let s0 = rng.normal_matrix(6, 6);
    let steps_t = 2.0f32; // integrate to t = 2
    let mut errors = Vec::new();
    for &eta in &[0.2f32, 0.1, 0.05] {
        let mut f = Factors { u: u0.clone(), s: s0.clone(), v: v0.clone() };
        let w0 = reconstruct(&f);
        let n_steps = (steps_t / eta) as usize;
        for _ in 0..n_steps {
            f = host_kls_step(&f, &a, eta, 0.0, false);
        }
        let w_exact = exact_flow(&a, &w0, steps_t);
        errors.push(reconstruct(&f).fro_dist(&w_exact));
    }
    // error must shrink roughly linearly with eta (Thm 1: c2·η term)
    assert!(
        errors[2] < errors[0] * 0.5 + 1e-3,
        "no first-order convergence: {errors:?}"
    );
    assert!(errors[2] < 0.2, "absolute error too large: {errors:?}");
}

#[test]
fn theorem1_robust_to_small_singular_values() {
    // the DLRA selling point (paper §5.1 "Robustness"): tiny σ in the
    // TARGET must not blow up the integrator error (no S⁻¹ anywhere).
    let mut rng = Rng::new(1);
    let a = target(20, 20, &[3.0, 1.0, 1e-4, 1e-6], &mut rng);
    let mut f = random_factors(20, 20, 8, &mut rng);
    for _ in 0..200 {
        f = host_kls_step(&f, &a, 0.1, 0.0, false);
        for v in f.s.data() {
            assert!(v.is_finite(), "integrator produced non-finite core");
        }
    }
    let err = reconstruct(&f).fro_dist(&a);
    assert!(err < 0.05, "did not converge near low-rank target: {err}");
}

#[test]
fn theorem2_loss_descends_monotonically_up_to_theta() {
    let mut rng = Rng::new(3);
    let a = target(16, 12, &[4.0, 2.0, 1.0], &mut rng);
    let mut f = random_factors(16, 12, 4, &mut rng);
    let tau = 0.05f32;
    let mut prev = loss(&reconstruct(&f), &a);
    for step in 0..60 {
        f = host_kls_step(&f, &a, 0.1, tau, true);
        let cur = loss(&reconstruct(&f), &a);
        // Thm 2: L(t+1) ≤ L(t) − αη + βϑ; allow the ϑ-sized slack
        let slack = tau * f.s.fro_norm() + 1e-5;
        assert!(
            cur <= prev + slack,
            "loss increased beyond ϑ-slack at step {step}: {prev} -> {cur}"
        );
        prev = cur;
    }
    assert!(prev < 1.0, "loss did not descend: {prev}");
}

#[test]
fn adaptive_rank_tracks_target_rank() {
    // start at rank 10; A has rank 3 with a clear spectral gap: the
    // τ-truncation must settle near rank 3
    let mut rng = Rng::new(5);
    let a = target(30, 30, &[10.0, 6.0, 3.0], &mut rng);
    let mut f = random_factors(30, 30, 10, &mut rng);
    for _ in 0..150 {
        f = host_kls_step(&f, &a, 0.1, 0.05, true);
    }
    let r = f.s.rows();
    assert!((2..=5).contains(&r), "rank {r} did not settle near target rank 3");
    assert!(reconstruct(&f).fro_dist(&a) < 0.1 * a.fro_norm());
}

#[test]
fn truncation_bound_controls_merged_serving_weight() {
    // The serving export merges W = U S Vᵀ into the pair (U, S·Vᵀ). This
    // property test ties that path to the paper's approximation guarantee
    // (§4.3 / Alg. 1 line 19): after a τ-truncation of the core, the
    // *merged inference weight* satisfies ‖W − W_trunc‖_F ≤ ϑ = τ‖Σ‖_F —
    // orthonormal bases preserve the Frobenius norm, so the error is
    // exactly the discarded tail energy, which truncation_rank bounds by ϑ.
    use dlrt::dlrt::LowRankFactors;
    use dlrt::serve::FrozenLayer;
    use dlrt::util::testutil::property;

    property(25, |rng| {
        let m = 12 + rng.below(20);
        let n = 10 + rng.below(24);
        let rmax = m.min(n);
        let r = (4 + rng.below(8)).min(rmax);
        let tau = [0.05f32, 0.15, 0.3][rng.below(3)];
        let f = LowRankFactors::random(m, n, r, rng);
        let w0 = f.reconstruct();

        // τ-truncate the core exactly as Alg. 1 does after freeze_ranks
        let svd = jacobi_svd(&f.s);
        let theta = tau * svd.sigma_fro();
        let r_new = svd.truncation_rank(theta, 1);
        assert!(r_new >= 1 && r_new <= r);
        let mut s_new = Matrix::zeros(r_new, r_new);
        for i in 0..r_new {
            s_new[(i, i)] = svd.sigma[i];
        }
        let truncated = LowRankFactors {
            u: matmul(&f.u, &svd.u.take_cols(r_new)),
            s: s_new,
            v: matmul(&f.v, &svd.vt.transpose().take_cols(r_new)),
            bias: f.bias.clone(),
        };

        // merge through the *serving* path and reconstruct the inference
        // weight the engine would actually apply (W = U · (V Sᵀ)ᵀ)
        let frozen = FrozenLayer::from_factors(&truncated);
        let FrozenLayer::LowRank { u, vs, .. } = &frozen else {
            panic!("factors must freeze to a merged low-rank layer");
        };
        assert_eq!((u.shape(), vs.shape()), ((m, r_new), (n, r_new)));
        let w_served = matmul_nt(u, vs);

        // float slack: QR/SVD orthonormality is ~1e-4, reconstruction adds
        // rounding proportional to ‖W‖
        let slack = 1e-3 * w0.fro_norm().max(1.0);
        let err = w_served.fro_dist(&w0);
        assert!(
            err <= theta + slack,
            "merged serving weight violates the truncation bound: \
             ‖W − U(SVᵀ)‖ = {err} > ϑ = {theta} (+{slack}) at τ={tau}, {m}x{n} r={r}→{r_new}"
        );
    });
}

#[test]
fn fixed_rank_flow_exactness_on_manifold() {
    // if W0 and A share the same rank-r subspaces, the fixed-rank KLS flow
    // must reproduce the exact flow to O(η²) per step ("exactness" of the
    // unconventional integrator [Ceruti-Lubich 2022])
    let mut rng = Rng::new(9);
    let r = 4;
    let u = householder_qr(&rng.normal_matrix(20, r));
    let v = householder_qr(&rng.normal_matrix(15, r));
    let sa = rng.normal_matrix(r, r);
    let s0 = rng.normal_matrix(r, r);
    let a = matmul(&matmul(&u, &sa), &v.transpose());
    let f0 = Factors { u: u.clone(), s: s0, v: v.clone() };
    let w0 = reconstruct(&f0);
    let eta = 0.05f32;
    let mut f = f0;
    for _ in 0..40 {
        f = host_kls_step(&f, &a, eta, 0.0, false);
    }
    let w_exact = exact_flow(&a, &w0, 40.0 * eta);
    let err = reconstruct(&f).fro_dist(&w_exact);
    assert!(err < 0.05, "on-manifold flow error {err}");
    let _ = matmul_nt; // used in other tests' sibling helpers
}
