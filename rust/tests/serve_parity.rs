//! Serving parity suite: the frozen-model export and the micro-batching
//! engine are locked to training evaluation for every layer mode the
//! repo trains (dense, vanilla, fixed-rank, adaptive, and the TRP-style
//! mixed `trp_lenet`).
//!
//! Three tiers of guarantee, from bitwise to tolerance:
//!
//! 1. **`forward_logits` ≡ `forward`** — scoring the backend's raw logits
//!    with the shared softmax reduction reproduces `Network::evaluate`'s
//!    loss/accuracy *exactly* (same floats): the serving primitive is the
//!    training forward, not a reimplementation.
//! 2. **Frozen ≈ live** — the merged-factor export preserves every argmax
//!    (up to numerical ties) and matches logits to reassociation
//!    tolerance; for all-dense nets the frozen forward is bitwise equal.
//! 3. **Reproducibility** — export → save → load → forward is bitwise,
//!    and every engine answer is bitwise equal to the frozen batch
//!    forward regardless of micro-batch composition.

use dlrt::config::{presets, Config, DataSource, Integrator, Mode};
use dlrt::coordinator::Trainer;
use dlrt::data::Batcher;
use dlrt::linalg::Matrix;
use dlrt::serve::{self, DrainPolicy, Engine, EngineConfig, FrozenModel};
use dlrt::util::testutil::TestDir;

fn toy_cfg(mode: Mode) -> Config {
    let mut cfg = presets::quickstart();
    cfg.mode = mode;
    cfg.epochs = 2;
    cfg.data = DataSource::Toy { n: 1_200 };
    cfg
}

/// Tiny TRP-LeNet run: dense conv prefix + adaptive tail, a few steps on
/// synthetic MNIST (bogus root so a local real dataset can't change the
/// trace or the runtime).
fn trp_cfg() -> Config {
    let mut cfg = presets::trp_lenet(0.3);
    cfg.epochs = 1;
    cfg.max_steps_per_epoch = 3;
    cfg.data = DataSource::Mnist { root: "data/__serve_parity__".into(), n_synth: 1_200 };
    cfg
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (j, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = j;
        }
    }
    best
}

/// Margin between the top two entries (0 for single-class rows).
fn top2_margin(xs: &[f32]) -> f32 {
    let b = argmax(xs);
    let mut second = f32::NEG_INFINITY;
    for (j, &v) in xs.iter().enumerate() {
        if j != b && v > second {
            second = v;
        }
    }
    if second.is_finite() {
        xs[b] - second
    } else {
        0.0
    }
}

/// Train the config, export, and run the full parity ladder. `exact_eval`
/// additionally demands bitwise loss/accuracy equality between the frozen
/// model and `Network::evaluate` (holds when no layer was merged, i.e.
/// all-dense nets).
fn assert_serve_parity(cfg: Config, name: &str, exact_eval: bool) {
    let mut t = Trainer::new(cfg).unwrap();
    t.run(name, |_| {}).unwrap();
    let data = t.split.test.clone();
    assert!(!data.is_empty());
    let cap = t.rt.batch_cap(&t.cfg.arch).unwrap();
    let (eval_loss, eval_acc) = t.model.evaluate(&t.rt, &data).unwrap();

    // --- tier 1: forward_logits reproduces evaluate() exactly -----------
    let params: Vec<_> = t.model.layers.iter().map(|l| l.params()).collect();
    let mut total_loss = 0.0f64;
    let mut total_correct = 0.0f64;
    let mut total = 0.0f64;
    let mut live_rows: Vec<Vec<f32>> = Vec::with_capacity(data.len());
    for batch in Batcher::sequential(&data, cap) {
        let logits = t.rt.forward_logits(&t.cfg.arch, &params, &batch).unwrap();
        assert_eq!(logits.shape(), (batch.w.len(), data.num_classes));
        let (loss, ncorrect) = serve::eval_logits(&logits, &batch.y, &batch.w).unwrap();
        total_loss += loss as f64 * batch.count as f64;
        total_correct += ncorrect as f64;
        total += batch.count as f64;
        for i in 0..batch.count {
            live_rows.push(logits.row(i).to_vec());
        }
    }
    assert_eq!(
        (total_loss / total) as f32,
        eval_loss,
        "[{name}] forward_logits + shared softmax must reproduce evaluate() loss exactly"
    );
    assert_eq!(
        (total_correct / total) as f32,
        eval_acc,
        "[{name}] forward_logits accuracy must reproduce evaluate() exactly"
    );

    // --- tier 2: frozen export preserves answers ------------------------
    let frozen = t.model.export();
    let x = Matrix::from_vec(data.len(), data.dim, data.features.clone());
    let frozen_logits = frozen.forward_logits(&x).unwrap();
    assert_eq!(frozen_logits.shape(), (data.len(), data.num_classes));
    let frozen_labels = frozen_logits.argmax_rows();
    for (i, live) in live_rows.iter().enumerate() {
        let frow = frozen_logits.row(i);
        let scale = live.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
        for (j, (&a, &b)) in live.iter().zip(frow).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * scale,
                "[{name}] sample {i} logit {j}: live {a} vs frozen {b}"
            );
        }
        // argmax must survive the merge whenever it isn't a numerical tie
        if top2_margin(live) > 1e-3 * scale {
            assert_eq!(
                frozen_labels[i],
                argmax(live),
                "[{name}] sample {i}: merged export flipped a decisive argmax"
            );
        }
    }
    let (frozen_loss, frozen_acc) = frozen.evaluate(&data, cap).unwrap();
    if exact_eval {
        assert_eq!(frozen_loss, eval_loss, "[{name}] dense frozen eval must be bitwise");
        assert_eq!(frozen_acc, eval_acc, "[{name}] dense frozen acc must be bitwise");
    } else {
        assert!(
            (frozen_loss - eval_loss).abs() <= 1e-3 * (1.0 + eval_loss.abs()),
            "[{name}] frozen loss {frozen_loss} vs live {eval_loss}"
        );
        assert!(
            (frozen_acc - eval_acc).abs() <= 0.02,
            "[{name}] frozen accuracy {frozen_acc} vs live {eval_acc}"
        );
    }

    // --- tier 3: save → load → forward is bitwise; engine == frozen -----
    let dir = TestDir::new();
    let path = dir.join(format!("{name}_frozen.json"));
    frozen.save(&path).unwrap();
    let loaded = FrozenModel::load(&path, &t.rt).unwrap();
    let logits2 = loaded.forward_logits(&x).unwrap();
    assert_eq!(
        frozen_logits.data(),
        logits2.data(),
        "[{name}] export → save → load → forward must be bitwise-reproducible"
    );

    // eager drains: sequential solo requests would wait out their SLO
    // slack for co-riders under the default policy (tests/serve_http.rs
    // and the queue unit tests cover SloSlack)
    let engine = Engine::start(
        loaded,
        EngineConfig {
            batch_cap: 8,
            replicas: 2,
            policy: DrainPolicy::Eager,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    for i in 0..data.len().min(8) {
        let pred = engine.infer(data.feature_row(i).to_vec()).unwrap();
        assert_eq!(
            pred.logits,
            frozen_logits.row(i).to_vec(),
            "[{name}] engine answer {i} differs from the frozen batch forward"
        );
        assert_eq!(pred.label, frozen_labels[i]);
    }
}

#[test]
fn parity_dense() {
    // no merged layer: the whole ladder holds bitwise
    assert_serve_parity(toy_cfg(Mode::Dense), "serve_dense", true);
}

#[test]
fn parity_vanilla() {
    let mut cfg = toy_cfg(Mode::Vanilla);
    cfg.fixed_rank = 8;
    // vanilla needs a gentler optimizer (Fig. 4's point)
    cfg.integrator = Integrator::Adam;
    cfg.lr = 0.005;
    // vanilla's core is the identity: the frozen layer carries the same
    // two factors training evaluated, so the whole ladder holds bitwise
    assert_serve_parity(cfg, "serve_vanilla", true);
}

#[test]
fn parity_fixed_dlrt() {
    let mut cfg = toy_cfg(Mode::FixedDlrt);
    cfg.fixed_rank = 8;
    assert_serve_parity(cfg, "serve_fixed", false);
}

#[test]
fn parity_adaptive_dlrt() {
    assert_serve_parity(toy_cfg(Mode::AdaptiveDlrt), "serve_adaptive", false);
}

#[test]
fn parity_trp_lenet_mixed() {
    // dense conv prefix + adaptive low-rank tail through the conv serving
    // path (im2col + pooling), the paper's deployment shape
    assert_serve_parity(trp_cfg(), "serve_trp_lenet", false);
}

#[test]
fn empty_dataset_eval_is_an_error_not_fake_stats() {
    // regression: evaluate() used to return (0.0, 0.0) — a "perfect" loss
    // — through a total.max(1.0) guard when the dataset was empty
    let mut cfg = toy_cfg(Mode::Dense);
    cfg.epochs = 1;
    let t = Trainer::new(cfg).unwrap();
    let empty = dlrt::data::Dataset {
        features: vec![],
        labels: vec![],
        dim: t.split.test.dim,
        num_classes: t.split.test.num_classes,
    };
    let err = t.model.evaluate(&t.rt, &empty).unwrap_err().to_string();
    assert!(err.contains("empty dataset"), "unhelpful error: {err}");
    let frozen = t.model.export();
    let err = frozen.evaluate(&empty, 32).unwrap_err().to_string();
    assert!(err.contains("empty dataset"), "unhelpful error: {err}");
}

#[test]
fn frozen_export_is_smaller_for_lowrank_nets() {
    // the deployment story: a truncated net stores (m+n)r + r² + m per
    // layer instead of mn + m — the export must realize that saving
    let mut cfg = toy_cfg(Mode::FixedDlrt);
    cfg.fixed_rank = 4;
    cfg.epochs = 1;
    let t = Trainer::new(cfg).unwrap();
    let frozen = t.model.export();
    assert!(
        frozen.stored_params() < frozen.dense_params(),
        "rank-4 frozen model must undercut dense storage: {} vs {}",
        frozen.stored_params(),
        frozen.dense_params()
    );
    // ranks surface for capacity planning
    assert_eq!(frozen.ranks().len(), t.model.layers.len());
}
