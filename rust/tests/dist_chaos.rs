//! Chaos suite for the distributed gradient coordinator (DESIGN.md §12).
//!
//! Every fault here ends in one of exactly two outcomes: the sweep
//! completes **bitwise-identical** to the in-process `ShardedExecutor`
//! at the same `grad_shards` (reassignment is invisible in the output),
//! or it fails with a descriptive error (never a hang, never a panic).
//!
//! Faulty workers are modeled two ways: in-test threads speaking the
//! wire protocol by hand (deterministic misbehavior — die mid-sweep,
//! hang forever, report an error), and a real `dlrt worker` subprocess
//! killed outright.

use dlrt::backend::{ComputeBackend, GradPhase, GradsOut, LayerGrads, LayerParams, NativeBackend};
use dlrt::data::Batch;
use dlrt::dlrt::LowRankFactors;
use dlrt::exec::dist::{self, DistExecutor, DistOptions};
use dlrt::exec::wire::{self, Msg, WireLayer};
use dlrt::linalg::{Matrix, Rng};
use dlrt::metrics::SystemClock;
use dlrt::runtime::Runtime;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// All-factored net on the `mlp_tiny` geometry (64 → 32 → 32 → 10):
/// small enough that a chaos run with reassignment finishes in well
/// under a second per sweep.
struct TinyNet {
    f: Vec<LowRankFactors>,
}

impl TinyNet {
    fn new(seed: u64) -> TinyNet {
        let mut rng = Rng::new(seed);
        let mut f = vec![
            LowRankFactors::random(32, 64, 8, &mut rng),
            LowRankFactors::random(32, 32, 8, &mut rng),
            LowRankFactors::random(10, 32, 10, &mut rng),
        ];
        for layer in &mut f {
            for b in layer.bias.iter_mut() {
                *b = 0.1 * rng.normal();
            }
        }
        TinyNet { f }
    }

    fn params(&self) -> Vec<LayerParams<'_>> {
        self.f
            .iter()
            .map(|l| LayerParams::Factored { u: &l.u, s: &l.s, v: &l.v, bias: &l.bias })
            .collect()
    }
}

/// 16-row toy batch (dim 64) with a padding tail and a fractional weight.
fn tiny_batch(seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let bsz = 16;
    let count = 14;
    let mut b = Batch {
        x: (0..bsz * 64).map(|_| rng.normal()).collect(),
        y: (0..bsz).map(|_| rng.below(10) as i32).collect(),
        w: vec![1.0; bsz],
        count,
    };
    for i in count..bsz {
        b.w[i] = 0.0;
        for v in &mut b.x[i * 64..(i + 1) * 64] {
            *v = 0.0;
        }
    }
    b.w[3] = 0.25;
    b
}

fn grads_bitwise_eq(a: &GradsOut, b: &GradsOut) -> bool {
    if a.loss.to_bits() != b.loss.to_bits() || a.ncorrect.to_bits() != b.ncorrect.to_bits() {
        return false;
    }
    let bits = |m: &Matrix, n: &Matrix| {
        m.shape() == n.shape()
            && m.data().iter().zip(n.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    let vbits = |p: &[f32], q: &[f32]| {
        p.len() == q.len() && p.iter().zip(q).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    a.layers.len() == b.layers.len()
        && a.layers.iter().zip(&b.layers).all(|(x, y)| match (x, y) {
            (LayerGrads::Kl { dk, dl }, LayerGrads::Kl { dk: a1, dl: a2 }) => {
                bits(dk, a1) && bits(dl, a2)
            }
            (LayerGrads::S { ds, db }, LayerGrads::S { ds: a1, db: a2 }) => {
                bits(ds, a1) && vbits(db, a2)
            }
            (LayerGrads::Dense { dw, db }, LayerGrads::Dense { dw: a1, db: a2 }) => {
                bits(dw, a1) && vbits(db, a2)
            }
            (
                LayerGrads::TwoFactor { du, dv, db },
                LayerGrads::TwoFactor { du: a1, dv: a2, db: a3 },
            ) => bits(du, a1) && bits(dv, a2) && vbits(db, a3),
            (LayerGrads::None, LayerGrads::None) => true,
            _ => false,
        })
}

/// A well-behaved in-test worker: the production loop over a client
/// socket, exactly what `dlrt worker` runs after connecting.
fn good_worker(addr: SocketAddr, id: u32) -> JoinHandle<()> {
    thread::spawn(move || {
        let stream = TcpStream::connect(addr).expect("good worker connect");
        let backend = NativeBackend::new();
        let _ = dist::serve_worker(stream, &backend, id);
    })
}

/// A worker that accepts its first job and dies mid-sweep without ever
/// answering — the "kill -9 between Job and Grads" failure.
fn dying_worker(addr: SocketAddr) -> JoinHandle<()> {
    thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("dying worker connect");
        wire::write_msg(&mut stream, &Msg::Hello { worker: 100 }).expect("hello");
        // brief (Sweep), then the first Job, then vanish
        let _ = wire::read_msg(&mut stream).expect("read sweep brief");
        let _ = wire::read_msg(&mut stream).expect("read first job");
        drop(stream);
    })
}

/// A worker that connects, reads everything, and never answers anything
/// — the straggler that must be struck by the per-worker deadline.
fn hung_worker(addr: SocketAddr) -> JoinHandle<()> {
    thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("hung worker connect");
        wire::write_msg(&mut stream, &Msg::Hello { worker: 200 }).expect("hello");
        while let Ok(Some(_)) = wire::read_msg_opt(&mut stream) {}
    })
}

/// A worker that answers its first job with a `WorkerErr` frame.
fn faulting_worker(addr: SocketAddr) -> JoinHandle<()> {
    thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("faulting worker connect");
        wire::write_msg(&mut stream, &Msg::Hello { worker: 300 }).expect("hello");
        let sweep = match wire::read_msg(&mut stream).expect("read sweep brief") {
            Msg::Sweep { sweep, .. } => sweep,
            _ => panic!("expected sweep brief"),
        };
        let shard = match wire::read_msg(&mut stream).expect("read first job") {
            Msg::Job { shard, .. } => shard,
            _ => panic!("expected job"),
        };
        let err = Msg::WorkerErr { sweep, shard, msg: "injected compute fault".into() };
        let _ = wire::write_msg(&mut stream, &err);
        // stay readable so coordinator writes don't race a closed socket
        while let Ok(Some(_)) = wire::read_msg_opt(&mut stream) {}
    })
}

fn adopt(
    listener: TcpListener,
    workers: usize,
    shards: usize,
    deadline: Duration,
    connect_window: Duration,
) -> dlrt::Result<DistExecutor> {
    let addr = listener.local_addr().expect("listener addr").to_string();
    let opts = DistOptions { workers, shards, deadline, addr, connect_window, delta: true };
    DistExecutor::adopt(listener, &opts, Arc::new(SystemClock))
}

fn in_process_reference(
    params: &[LayerParams<'_>],
    phase: GradPhase,
    batch: &Batch,
    shards: usize,
) -> GradsOut {
    Runtime::native()
        .with_grad_shards(shards)
        .expect("sharded runtime")
        .grads("mlp_tiny", params, phase, batch)
        .expect("in-process reference")
}

#[test]
fn killed_worker_mid_sweep_is_reassigned_and_stays_bitwise() {
    let net = TinyNet::new(0xC4A05);
    let params = net.params();
    let batch = tiny_batch(1);
    let shards = 4;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let h1 = dying_worker(addr);
    let h2 = good_worker(addr, 1);
    let dist = adopt(listener, 2, shards, Duration::from_secs(10), Duration::from_secs(10))
        .expect("adopt");
    assert_eq!(dist.connected_workers(), 2);
    let backend = NativeBackend::new();
    for phase in [GradPhase::Kl, GradPhase::S] {
        let out = dist
            .grads(&backend, "mlp_tiny", &params, phase, &batch)
            .expect("sweep must survive a worker dying mid-flight");
        let reference = in_process_reference(&params, phase, &batch, shards);
        assert!(
            grads_bitwise_eq(&out, &reference),
            "{phase:?}: reassigned sweep drifted from the no-failure in-process result"
        );
    }
    // the dead worker must be off the roster; the survivor carried it
    assert_eq!(dist.live_workers(), 1);
    dist.shutdown();
    drop(dist);
    h1.join().expect("dying worker thread");
    h2.join().expect("good worker thread");
}

#[test]
fn killed_real_worker_process_is_reassigned_and_stays_bitwise() {
    let net = TinyNet::new(0xDEAD);
    let params = net.params();
    let batch = tiny_batch(2);
    let shards = 4;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let exe = env!("CARGO_BIN_EXE_dlrt");
    let mut children: Vec<_> = (0..2)
        .map(|i| {
            Command::new(exe)
                .arg("worker")
                .arg("--connect")
                .arg(addr.to_string())
                .arg("--id")
                .arg(i.to_string())
                .stdin(Stdio::null())
                .spawn()
                .expect("spawn dlrt worker")
        })
        .collect();
    let dist = adopt(listener, 2, shards, Duration::from_secs(10), Duration::from_secs(30))
        .expect("adopt");
    assert_eq!(dist.connected_workers(), 2);
    // kill one real process before the sweep; the coordinator sees EOF on
    // its socket mid-sweep and must shift every shard to the survivor
    children[0].kill().expect("kill worker 0");
    children[0].wait().expect("reap worker 0");
    let backend = NativeBackend::new();
    let out = dist
        .grads(&backend, "mlp_tiny", &params, GradPhase::Kl, &batch)
        .expect("sweep must survive a killed worker process");
    let reference = in_process_reference(&params, GradPhase::Kl, &batch, shards);
    assert!(
        grads_bitwise_eq(&out, &reference),
        "sweep after a real process kill drifted from the in-process result"
    );
    dist.shutdown();
    drop(dist);
    for child in children.iter_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
}

#[test]
fn worker_that_never_connects_is_tolerated() {
    let net = TinyNet::new(0x90057);
    let params = net.params();
    let batch = tiny_batch(3);
    let shards = 3;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    // 2 workers expected, only 1 ever shows up; the connect window
    // expires and the coordinator proceeds short-handed
    let h = good_worker(addr, 0);
    let dist = adopt(listener, 2, shards, Duration::from_secs(10), Duration::from_millis(500))
        .expect("adopt must tolerate a no-show when at least one connects");
    assert_eq!(dist.connected_workers(), 1);
    let backend = NativeBackend::new();
    let out = dist
        .grads(&backend, "mlp_tiny", &params, GradPhase::Kl, &batch)
        .expect("short-handed sweep");
    let reference = in_process_reference(&params, GradPhase::Kl, &batch, shards);
    assert!(
        grads_bitwise_eq(&out, &reference),
        "short-handed sweep drifted from the in-process result"
    );
    dist.shutdown();
    drop(dist);
    h.join().expect("good worker thread");
}

#[test]
fn no_workers_at_all_is_a_descriptive_error_not_a_hang() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let err = adopt(listener, 2, 4, Duration::from_secs(1), Duration::from_millis(250))
        .expect_err("adopt with zero connections must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker"), "unhelpful adopt error: {msg}");
}

#[test]
fn hung_worker_past_deadline_is_struck_and_its_shards_reassigned() {
    let net = TinyNet::new(0x4A46);
    let params = net.params();
    let batch = tiny_batch(4);
    let shards = 4;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let h1 = hung_worker(addr);
    let h2 = good_worker(addr, 1);
    // tight per-worker deadline: the hung worker's shards must time out,
    // strike it, and land on the live one
    let dist = adopt(listener, 2, shards, Duration::from_millis(200), Duration::from_secs(10))
        .expect("adopt");
    assert_eq!(dist.connected_workers(), 2);
    let backend = NativeBackend::new();
    let out = dist
        .grads(&backend, "mlp_tiny", &params, GradPhase::Kl, &batch)
        .expect("sweep must survive a hung worker");
    let reference = in_process_reference(&params, GradPhase::Kl, &batch, shards);
    assert!(
        grads_bitwise_eq(&out, &reference),
        "sweep with a struck straggler drifted from the in-process result"
    );
    assert_eq!(dist.live_workers(), 1, "the straggler must be struck from the roster");
    dist.shutdown();
    drop(dist);
    h1.join().expect("hung worker thread");
    h2.join().expect("good worker thread");
}

#[test]
fn fresh_worker_answers_a_delta_with_need_full_and_still_computes_bitwise() {
    // The fresh-spawn / struck-and-replaced scenario (DESIGN.md §13): a
    // worker holding no snapshot receives a `SweepDelta` as its first
    // brief. It must not compute on parameters it does not hold — it
    // answers `NeedFull`, parks the job that raced ahead of the resync,
    // and serves it only after the full brief lands, bitwise-identical to
    // a direct backend call.
    let net = TinyNet::new(0x4E5);
    let params = net.params();
    let batch = tiny_batch(6);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let h = good_worker(addr, 9);
    let (mut coord, _) = listener.accept().expect("accept");
    match wire::read_msg(&mut coord).expect("hello") {
        Msg::Hello { .. } => {}
        _ => panic!("worker must open with Hello"),
    }
    let layers: Vec<WireLayer> = params.iter().map(WireLayer::from_params).collect();
    let hashes: Vec<u64> =
        layers.iter().map(|l| wire::layer_hash(l).expect("layer hash")).collect();
    let sweep = 41;
    let delta = Msg::SweepDelta {
        sweep,
        arch: "mlp_tiny".into(),
        phase: GradPhase::Kl,
        layer_hashes: hashes,
        changed: Vec::new(),
    };
    wire::write_msg(&mut coord, &delta).expect("send delta to cold worker");
    // a job races ahead of the resync — it must park, not fail
    let job = Msg::Job { sweep, shard: 0, batch: batch.clone() };
    wire::write_msg(&mut coord, &job).expect("send job");
    match wire::read_msg(&mut coord).expect("worker reply") {
        Msg::NeedFull { sweep: s } => assert_eq!(s, sweep, "NeedFull names the wrong sweep"),
        _ => panic!("a cold worker must answer a delta brief with NeedFull"),
    }
    let full = Msg::Sweep { sweep, arch: "mlp_tiny".into(), phase: GradPhase::Kl, layers };
    wire::write_msg(&mut coord, &full).expect("send full resync");
    let out = match wire::read_msg(&mut coord).expect("grads reply") {
        Msg::Grads { sweep: s, shard, out } => {
            assert_eq!((s, shard), (sweep, 0), "parked job answered under the wrong identity");
            out
        }
        Msg::WorkerErr { msg, .. } => panic!("worker refused the parked job: {msg}"),
        _ => panic!("expected Grads for the parked job"),
    };
    let reference = NativeBackend::new()
        .grads("mlp_tiny", &params, GradPhase::Kl, &batch)
        .expect("direct backend reference");
    assert!(
        grads_bitwise_eq(&out, &reference),
        "post-resync gradients drifted from the direct backend call"
    );
    wire::write_msg(&mut coord, &Msg::Shutdown).expect("shutdown");
    h.join().expect("worker thread");
}

#[test]
fn coordinator_refusing_need_full_is_a_protocol_failure_not_a_hang() {
    // A second delta for the sweep the worker already answered `NeedFull`
    // for means the coordinator refuses to resync it; the worker must die
    // with the distinct protocol exit code instead of waiting forever (or
    // worse, computing on parameters it never received).
    let net = TinyNet::new(0xBAD5);
    let params = net.params();
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let h = thread::spawn(move || {
        let stream = TcpStream::connect(addr).expect("worker connect");
        let backend = NativeBackend::new();
        dist::serve_worker(stream, &backend, 5)
    });
    let (mut coord, _) = listener.accept().expect("accept");
    match wire::read_msg(&mut coord).expect("hello") {
        Msg::Hello { .. } => {}
        _ => panic!("worker must open with Hello"),
    }
    let layers: Vec<WireLayer> = params.iter().map(WireLayer::from_params).collect();
    let hashes: Vec<u64> =
        layers.iter().map(|l| wire::layer_hash(l).expect("layer hash")).collect();
    let delta = Msg::SweepDelta {
        sweep: 7,
        arch: "mlp_tiny".into(),
        phase: GradPhase::Kl,
        layer_hashes: hashes,
        changed: Vec::new(),
    };
    wire::write_msg(&mut coord, &delta).expect("first delta");
    match wire::read_msg(&mut coord).expect("worker reply") {
        Msg::NeedFull { sweep } => assert_eq!(sweep, 7),
        _ => panic!("cold worker must answer NeedFull"),
    }
    wire::write_msg(&mut coord, &delta).expect("refuse the resync with a second delta");
    let err = h
        .join()
        .expect("worker thread")
        .expect_err("a refused NeedFull must fail the worker");
    let wf = err
        .downcast_ref::<dist::WorkerFailure>()
        .expect("worker death must carry a classified WorkerFailure");
    assert_eq!(wf.code, dist::EXIT_PROTOCOL, "refused resync is a protocol failure");
    assert!(wf.reason.contains("NeedFull"), "reason must name the refusal: {}", wf.reason);
}

#[test]
fn killed_worker_after_warm_caches_keeps_delta_sweeps_bitwise() {
    // Kill-then-continue under delta briefs: warm both caches over two
    // sweeps (the second rides the delta path), kill one real worker
    // process, mutate a layer, and the next delta sweep must complete on
    // the survivor — bitwise-identical to the in-process executor.
    let mut net = TinyNet::new(0x5A17);
    let batch = tiny_batch(7);
    let shards = 4;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let exe = env!("CARGO_BIN_EXE_dlrt");
    let mut children: Vec<_> = (0..2)
        .map(|i| {
            Command::new(exe)
                .arg("worker")
                .arg("--connect")
                .arg(addr.to_string())
                .arg("--id")
                .arg(i.to_string())
                .stdin(Stdio::null())
                .spawn()
                .expect("spawn dlrt worker")
        })
        .collect();
    let dist = adopt(listener, 2, shards, Duration::from_secs(10), Duration::from_secs(30))
        .expect("adopt");
    assert_eq!(dist.connected_workers(), 2);
    let backend = NativeBackend::new();
    for _ in 0..2 {
        let params = net.params();
        let out = dist
            .grads(&backend, "mlp_tiny", &params, GradPhase::Kl, &batch)
            .expect("warmup sweep");
        let reference = in_process_reference(&params, GradPhase::Kl, &batch, shards);
        assert!(grads_bitwise_eq(&out, &reference), "warmup sweep drifted");
    }
    assert!(
        dist.wire_stats().snapshot().delta_hits > 0,
        "the warm re-sweep must ride the delta path"
    );
    children[0].kill().expect("kill worker 0");
    children[0].wait().expect("reap worker 0");
    for b in net.f[0].bias.iter_mut() {
        *b += 0.5;
    }
    let params = net.params();
    let out = dist
        .grads(&backend, "mlp_tiny", &params, GradPhase::Kl, &batch)
        .expect("delta sweep must survive a killed worker");
    let reference = in_process_reference(&params, GradPhase::Kl, &batch, shards);
    assert!(
        grads_bitwise_eq(&out, &reference),
        "post-kill delta sweep drifted from the in-process result"
    );
    dist.shutdown();
    drop(dist);
    for child in children.iter_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
}

#[test]
fn worker_reported_fault_surfaces_as_an_error() {
    let net = TinyNet::new(0xE44);
    let params = net.params();
    let batch = tiny_batch(5);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let h1 = faulting_worker(addr);
    let h2 = good_worker(addr, 1);
    let dist = adopt(listener, 2, 4, Duration::from_secs(10), Duration::from_secs(10))
        .expect("adopt");
    assert_eq!(dist.connected_workers(), 2);
    let backend = NativeBackend::new();
    let err = dist
        .grads(&backend, "mlp_tiny", &params, GradPhase::Kl, &batch)
        .expect_err("a worker-reported compute fault must fail the sweep");
    let msg = format!("{err:#}");
    assert!(msg.contains("injected compute fault"), "fault text lost: {msg}");
    dist.shutdown();
    drop(dist);
    h1.join().expect("faulting worker thread");
    h2.join().expect("good worker thread");
}
