//! Multi-process parity suite (DESIGN.md §12): gradients computed by
//! real `dlrt worker` subprocesses behind the [`DistExecutor`] must be
//! **bitwise-identical** to the in-process [`ShardedExecutor`] at the
//! same `grad_shards` — the wire layer round-trips f32 bit patterns, the
//! batch split is the same pure function, and the reduction order is
//! fixed by shard index, so nothing about crossing a process boundary is
//! allowed to move a single bit.
//!
//! The worker binary comes from `env!("CARGO_BIN_EXE_dlrt")` (Cargo
//! builds and exposes the real CLI to integration tests); the test binds
//! its own loopback listener and adopts the spawned workers.
//!
//! Delta-encoded sweep briefs (DESIGN.md §13) are covered here too: the
//! same multi-sweep schedule runs through a delta-enabled cluster, a
//! delta-disabled cluster, and the in-process executor, and all three
//! must agree bitwise — the transport decision is not allowed to be
//! visible in the output.

use dlrt::backend::{ComputeBackend, GradPhase, GradsOut, LayerGrads, LayerParams, NativeBackend};
use dlrt::baselines::he_normal;
use dlrt::data::Batch;
use dlrt::dlrt::LowRankFactors;
use dlrt::exec::dist::{DistExecutor, DistOptions};
use dlrt::linalg::{Matrix, Rng};
use dlrt::metrics::SystemClock;
use dlrt::runtime::Runtime;
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

/// Dense-conv prefix + adaptive low-rank tail on the `lenet` geometry
/// (same property net as `tests/shard_exec.rs`): conv 20x25, conv 50x500
/// (dense kernels) | fc 500x800, fc 10x500 (factored).
struct MixedNet {
    w0: Matrix,
    b0: Vec<f32>,
    w1: Matrix,
    b1: Vec<f32>,
    f2: LowRankFactors,
    f3: LowRankFactors,
}

impl MixedNet {
    fn new(seed: u64) -> MixedNet {
        let mut rng = Rng::new(seed);
        let mut net = MixedNet {
            w0: he_normal(20, 25, &mut rng),
            b0: (0..20).map(|_| 0.1 * rng.normal()).collect(),
            w1: he_normal(50, 500, &mut rng),
            b1: (0..50).map(|_| 0.1 * rng.normal()).collect(),
            f2: LowRankFactors::random(500, 800, 16, &mut rng),
            f3: LowRankFactors::random(10, 500, 10, &mut rng),
        };
        for b in net.f2.bias.iter_mut().chain(net.f3.bias.iter_mut()) {
            *b = 0.1 * rng.normal();
        }
        net
    }

    fn params(&self) -> Vec<LayerParams<'_>> {
        vec![
            LayerParams::Dense { w: &self.w0, bias: &self.b0 },
            LayerParams::Dense { w: &self.w1, bias: &self.b1 },
            LayerParams::Factored {
                u: &self.f2.u,
                s: &self.f2.s,
                v: &self.f2.v,
                bias: &self.f2.bias,
            },
            LayerParams::Factored {
                u: &self.f3.u,
                s: &self.f3.s,
                v: &self.f3.v,
                bias: &self.f3.bias,
            },
        ]
    }
}

/// A 24-row MNIST-shaped batch with a padding tail and one fractional
/// weight, so the Σw-weighted reduction is actually exercised.
fn lenet_batch(seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let bsz = 24;
    let count = 20;
    let mut b = Batch {
        x: (0..bsz * 784).map(|_| rng.normal()).collect(),
        y: (0..bsz).map(|_| rng.below(10) as i32).collect(),
        w: vec![1.0; bsz],
        count,
    };
    for i in count..bsz {
        b.w[i] = 0.0;
        for v in &mut b.x[i * 784..(i + 1) * 784] {
            *v = 0.0;
        }
    }
    b.w[5] = 0.5;
    b
}

fn grads_bitwise_eq(a: &GradsOut, b: &GradsOut) -> bool {
    if a.loss.to_bits() != b.loss.to_bits() || a.ncorrect.to_bits() != b.ncorrect.to_bits() {
        return false;
    }
    let bits = |m: &Matrix, n: &Matrix| {
        m.shape() == n.shape()
            && m.data().iter().zip(n.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    let vbits = |p: &[f32], q: &[f32]| {
        p.len() == q.len() && p.iter().zip(q).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    a.layers.len() == b.layers.len()
        && a.layers.iter().zip(&b.layers).all(|(x, y)| match (x, y) {
            (LayerGrads::Kl { dk, dl }, LayerGrads::Kl { dk: a1, dl: a2 }) => {
                bits(dk, a1) && bits(dl, a2)
            }
            (LayerGrads::S { ds, db }, LayerGrads::S { ds: a1, db: a2 }) => {
                bits(ds, a1) && vbits(db, a2)
            }
            (LayerGrads::Dense { dw, db }, LayerGrads::Dense { dw: a1, db: a2 }) => {
                bits(dw, a1) && vbits(db, a2)
            }
            (
                LayerGrads::TwoFactor { du, dv, db },
                LayerGrads::TwoFactor { du: a1, dv: a2, db: a3 },
            ) => bits(du, a1) && bits(dv, a2) && vbits(db, a3),
            (LayerGrads::None, LayerGrads::None) => true,
            _ => false,
        })
}

/// Bind a loopback listener, launch `workers` real `dlrt worker`
/// subprocesses pointed at it, and adopt them into a coordinator.
/// Callers must [`reap`] the children when done.
fn real_worker_cluster(workers: usize, shards: usize, delta: bool) -> (DistExecutor, Vec<Child>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback listener");
    let addr = listener.local_addr().expect("listener addr");
    let exe = env!("CARGO_BIN_EXE_dlrt");
    let children: Vec<Child> = (0..workers)
        .map(|i| {
            Command::new(exe)
                .arg("worker")
                .arg("--connect")
                .arg(addr.to_string())
                .arg("--id")
                .arg(i.to_string())
                .stdin(Stdio::null())
                .spawn()
                .expect("spawn dlrt worker")
        })
        .collect();
    let opts = DistOptions {
        workers,
        shards,
        deadline: Duration::from_secs(30),
        addr: addr.to_string(),
        connect_window: Duration::from_secs(30),
        delta,
    };
    let dist = DistExecutor::adopt(listener, &opts, Arc::new(SystemClock))
        .expect("adopt spawned workers");
    assert_eq!(dist.connected_workers(), workers, "every worker must connect");
    (dist, children)
}

fn reap(dist: DistExecutor, mut children: Vec<Child>) {
    dist.shutdown();
    drop(dist);
    for child in children.iter_mut() {
        // Shutdown frame lets workers exit on their own; kill is the
        // backstop so a wedged worker can't hang the test suite
        let _ = child.kill();
        let _ = child.wait();
    }
}

#[test]
fn multi_process_grads_bitwise_match_in_process_sharded() {
    let net = MixedNet::new(0xA11CE);
    let params = net.params();
    let batch = lenet_batch(7);
    let shards = 4;
    let in_process = Runtime::native().with_grad_shards(shards).expect("sharded runtime");
    let backend = NativeBackend::new();
    for workers in [2usize, 3] {
        let (dist, children) = real_worker_cluster(workers, shards, true);
        for phase in [GradPhase::Kl, GradPhase::S] {
            let reference = in_process.grads("lenet", &params, phase, &batch).expect("in-process");
            let distributed =
                dist.grads(&backend, "lenet", &params, phase, &batch).expect("multi-process");
            assert!(
                grads_bitwise_eq(&distributed, &reference),
                "workers={workers} {phase:?}: multi-process gradients drifted from the \
                 in-process ShardedExecutor at grad_shards={shards}"
            );
        }
        reap(dist, children);
    }
}

#[test]
fn repeated_distributed_sweeps_are_bitwise_deterministic() {
    // sweep ids advance and streams are reused across calls; neither may
    // move a bit
    let net = MixedNet::new(0xDE7);
    let params = net.params();
    let batch = lenet_batch(8);
    let backend = NativeBackend::new();
    let (dist, children) = real_worker_cluster(2, 3, true);
    let a = dist.grads(&backend, "lenet", &params, GradPhase::Kl, &batch).expect("first sweep");
    let b = dist.grads(&backend, "lenet", &params, GradPhase::Kl, &batch).expect("second sweep");
    let c = dist.grads(&backend, "lenet", &params, GradPhase::Kl, &batch).expect("third sweep");
    assert!(grads_bitwise_eq(&a, &b), "distributed rerun drifted");
    assert!(grads_bitwise_eq(&a, &c), "distributed rerun drifted on the third sweep");
    // re-sweeps of an unchanged snapshot must ride the delta path: both
    // workers hold sweep 1's brief, so sweeps 2 and 3 are hash-only deltas
    let snap = dist.wire_stats().snapshot();
    assert!(
        snap.delta_hits >= 4,
        "expected >= 4 delta brief deliveries (2 workers x 2 re-sweeps), got {}",
        snap.delta_hits
    );
    reap(dist, children);
}

#[test]
fn delta_briefs_match_full_briefs_and_in_process_bitwise() {
    // The transport decision (delta vs full brief) must be invisible in
    // the gradients. Run one multi-sweep schedule — repeated sweeps on an
    // unchanged snapshot (caches engage, hash-only deltas), then a
    // mutated layer (the delta ships exactly the changed layer) — through
    // a delta-enabled cluster, a delta-disabled cluster, and the
    // in-process sharded executor, and compare every sweep bitwise.
    let shards = 4;
    let batch = lenet_batch(13);
    let backend = NativeBackend::new();
    for workers in [2usize, 3] {
        let (delta_dist, delta_children) = real_worker_cluster(workers, shards, true);
        let (full_dist, full_children) = real_worker_cluster(workers, shards, false);
        assert!(delta_dist.delta_enabled());
        assert!(!full_dist.delta_enabled());
        let in_process = Runtime::native().with_grad_shards(shards).expect("sharded runtime");
        let mut net = MixedNet::new(0xD317A);
        for step in 0..3 {
            if step == 2 {
                // one layer changes between sweeps: only it may ride the
                // delta, and the worker-side patched cache must hash-match
                // the full snapshot before any job is computed
                for v in net.b1.iter_mut() {
                    *v += 0.25;
                }
            }
            let params = net.params();
            for phase in [GradPhase::Kl, GradPhase::S] {
                let reference =
                    in_process.grads("lenet", &params, phase, &batch).expect("in-process");
                let via_delta = delta_dist
                    .grads(&backend, "lenet", &params, phase, &batch)
                    .expect("delta-cluster sweep");
                let via_full = full_dist
                    .grads(&backend, "lenet", &params, phase, &batch)
                    .expect("full-cluster sweep");
                assert!(
                    grads_bitwise_eq(&via_delta, &reference),
                    "workers={workers} step={step} {phase:?}: delta-brief cluster drifted \
                     from the in-process executor"
                );
                assert!(
                    grads_bitwise_eq(&via_full, &reference),
                    "workers={workers} step={step} {phase:?}: full-brief cluster drifted \
                     from the in-process executor"
                );
            }
        }
        // the schedule must actually have exercised both transports
        let d = delta_dist.wire_stats().snapshot();
        assert!(d.delta_hits > 0, "delta cluster never delivered a delta brief");
        let f = full_dist.wire_stats().snapshot();
        assert_eq!(f.delta_hits, 0, "delta-disabled cluster delivered a delta brief");
        assert!(
            d.bytes_tx < f.bytes_tx,
            "delta briefs did not reduce bytes on the wire ({} vs {})",
            d.bytes_tx,
            f.bytes_tx
        );
        reap(delta_dist, delta_children);
        reap(full_dist, full_children);
    }
}

#[test]
fn steady_state_sweep_encode_draws_from_the_scratch_pool() {
    // Acceptance (DESIGN.md §13): once the size hints are warm, the
    // coordinator's sweep encode path (brief broadcast + job sends) draws
    // every buffer from the global scratch pool instead of allocating.
    let net = MixedNet::new(0x57EAD);
    let params = net.params();
    let batch = lenet_batch(17);
    let backend = NativeBackend::new();
    let (dist, children) = real_worker_cluster(2, 3, true);
    let pool = dlrt::util::scratch::global();
    for _ in 0..3 {
        dist.grads(&backend, "lenet", &params, GradPhase::Kl, &batch).expect("warmup sweep");
    }
    // The global pool is shared with concurrently running tests, so one
    // window can see a foreign checkout steal a pooled buffer; require a
    // clean window rather than forbidding all interference.
    let mut flat = false;
    for _ in 0..8 {
        let before = pool.fresh_allocs();
        for _ in 0..2 {
            dist.grads(&backend, "lenet", &params, GradPhase::Kl, &batch).expect("steady sweep");
        }
        if pool.fresh_allocs() == before {
            flat = true;
            break;
        }
    }
    assert!(flat, "steady-state sweeps kept allocating fresh encode buffers");
    reap(dist, children);
}

#[test]
fn shards_one_is_a_direct_backend_passthrough() {
    // the in-process fast path must not even touch the wire: results are
    // bitwise-identical to the direct backend call
    let net = MixedNet::new(0xF00D);
    let params = net.params();
    let batch = lenet_batch(9);
    let backend = NativeBackend::new();
    let (dist, children) = real_worker_cluster(2, 1, true);
    for phase in [GradPhase::Kl, GradPhase::S] {
        let direct = backend.grads("lenet", &params, phase, &batch).expect("direct");
        let through = dist.grads(&backend, "lenet", &params, phase, &batch).expect("dist k=1");
        assert!(
            grads_bitwise_eq(&through, &direct),
            "shards=1 through the DistExecutor is not a bitwise passthrough ({phase:?})"
        );
    }
    reap(dist, children);
}

#[test]
fn runtime_routes_grads_through_an_attached_dist_executor() {
    // the Runtime::grads dispatch: with a dist executor attached, sweeps
    // go multi-process and still match the in-process sharded runtime
    let net = MixedNet::new(0xBEEF);
    let params = net.params();
    let batch = lenet_batch(11);
    let shards = 2;
    let reference = Runtime::native()
        .with_grad_shards(shards)
        .expect("sharded runtime")
        .grads("lenet", &params, GradPhase::Kl, &batch)
        .expect("in-process");
    let (dist, children) = real_worker_cluster(2, shards, true);
    let rt = Runtime::native().with_grad_shards(shards).expect("runtime").with_dist(dist);
    assert!(rt.dist().is_some());
    let out = rt.grads("lenet", &params, GradPhase::Kl, &batch).expect("runtime dist grads");
    assert!(
        grads_bitwise_eq(&out, &reference),
        "Runtime-attached dist executor drifted from the in-process path"
    );
    // evaluation forwards stay in-process by design — they must still work
    let stats = rt.forward("lenet", &params, &batch).expect("in-process forward");
    assert!(stats.loss.is_finite());
    let mut children = children;
    drop(rt); // drops the dist executor → Shutdown frames
    for child in children.iter_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
}
