//! Full-stack training integration: the Trainer on every pure mode and on
//! mixed per-layer nets, rank adaptation, pruning + retraining, paranoid
//! self-checks, and checkpoint round-trips (v1 + v2, resume-equivalence) —
//! all on the hermetic native backend. Uses the tiny arch + toy data so
//! each test completes in seconds.

use dlrt::baselines::svd_prune_factors;
use dlrt::config::{presets, Config, DataSource, Integrator, Mode};
use dlrt::coordinator::{
    load_network, restore_network, save_network, Trainer, ValOrTest,
};
use dlrt::linalg::orthonormality_error;
use dlrt::util::testutil::TestDir;

fn toy_cfg(mode: Mode) -> Config {
    let mut cfg = presets::quickstart();
    cfg.mode = mode;
    cfg.epochs = 3;
    cfg.data = DataSource::Toy { n: 1_200 };
    cfg
}

/// TRP-style mixed toy config: dense first layer, adaptive low-rank tail.
fn toy_mixed_cfg() -> Config {
    let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
    cfg.layer_modes = vec![Mode::Dense, Mode::AdaptiveDlrt, Mode::AdaptiveDlrt];
    cfg
}

#[test]
fn adaptive_dlrt_learns_toy_task_and_compresses() {
    let mut t = Trainer::new(toy_cfg(Mode::AdaptiveDlrt)).unwrap();
    let rec = t.run("it_adaptive", |_| {}).unwrap();
    assert!(
        rec.test_acc > 0.80,
        "adaptive DLRT should learn the toy task (acc {})",
        rec.test_acc
    );
    // ranks must have dropped below the init rank 16 on the wide layers
    assert!(rec.final_ranks[0] < 16, "no compression happened: {:?}", rec.final_ranks);
    // pinned classifier head stays at full rank 10
    assert_eq!(*rec.final_ranks.last().unwrap(), 10);
    // loss history is broadly decreasing
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn fixed_dlrt_and_dense_and_vanilla_all_train() {
    for (mode, min_acc) in
        [(Mode::FixedDlrt, 0.7), (Mode::Dense, 0.8), (Mode::Vanilla, 0.6)]
    {
        let mut cfg = toy_cfg(mode);
        cfg.fixed_rank = 8;
        if mode == Mode::Vanilla {
            // vanilla needs a gentler lr (ill-conditioning is the point of Fig.4)
            cfg.integrator = Integrator::Adam;
            cfg.lr = 0.005;
        }
        let mut t = Trainer::new(cfg).unwrap();
        let rec = t.run("it_mode", |_| {}).unwrap();
        assert!(
            rec.test_acc > min_acc,
            "{mode:?} failed to learn (acc {})",
            rec.test_acc
        );
    }
}

#[test]
fn mixed_net_trains_on_toy_task() {
    // dense layer 0 + adaptive layers 1-2 in one Network: the per-layer
    // core's bread and butter, at toy scale
    let mut t = Trainer::new(toy_mixed_cfg()).unwrap();
    assert_eq!(t.model.layers[0].kind(), "dense");
    assert!(t.model.layers[1].is_factored());
    let rec = t.run("it_mixed", |_| {}).unwrap();
    assert!(rec.test_acc > 0.75, "mixed net failed to learn (acc {})", rec.test_acc);
    // the dense layer reports full rank, the adaptive middle truncates
    // below its 32x32 max rank (it would sit at the full 32 if the
    // augment-then-truncate loop never cut anything)
    assert_eq!(rec.final_ranks[0], 32); // dense 32x64
    assert!(rec.final_ranks[1] < 32, "adaptive tail never truncated: {:?}", rec.final_ranks);
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "mixed loss did not decrease: {first} -> {last}");
}

#[test]
fn paranoid_run_on_healthy_net_succeeds_and_checks_orthonormality() {
    // Config.paranoid is wired through the Trainer into the per-step basis
    // assertions of the model core: a healthy run passes them all
    let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
    cfg.paranoid = true;
    cfg.epochs = 2;
    let mut t = Trainer::new(cfg).unwrap();
    assert!(t.model.paranoid, "cfg.paranoid must reach the network");
    t.run("it_paranoid", |_| {}).unwrap();
    for (i, ls) in t.model.layers.iter().enumerate() {
        let f = &ls.dlrt().expect("all-DLRT net").factors;
        assert!(
            orthonormality_error(&f.u) < 1e-3,
            "layer {i}: U drifted off the Stiefel manifold"
        );
        assert!(orthonormality_error(&f.v) < 1e-3, "layer {i}: V drifted");
    }
}

#[test]
fn rank_freeze_stops_adaptation() {
    let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
    cfg.epochs = 3;
    cfg.freeze_rank_after_epochs = 1;
    let mut t = Trainer::new(cfg).unwrap();
    let mut rank_history: Vec<Vec<usize>> = Vec::new();
    t.run("it_freeze", |e| rank_history.push(e.ranks.clone())).unwrap();
    // after the freeze epoch, ranks must be constant
    assert_eq!(rank_history[1], rank_history[2], "ranks changed after freeze");
    // freezing converted the adaptive layers to fixed-rank
    assert!(!t.model.adaptive(), "freeze must leave no adaptive layer");
}

#[test]
fn svd_prune_collapses_then_retraining_recovers() {
    // Table 8's mechanism at toy scale: truncation destroys accuracy,
    // fixed-rank DLRT retraining restores it.
    let mut cfg = toy_cfg(Mode::Dense);
    cfg.epochs = 3;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let dense_rec = t.run("it_dense_base", |_| {}).unwrap();
    assert!(dense_rec.test_acc > 0.85);

    let pruned = svd_prune_factors(&t.model, 2); // aggressive rank-2 truncation

    // evaluate the raw truncation (no retraining)
    let mut cfg_eval = cfg.clone();
    cfg_eval.mode = Mode::FixedDlrt;
    let t_pruned =
        Trainer::new(cfg_eval.clone()).unwrap().with_factors(pruned.clone(), false).unwrap();
    let (_, acc_raw) = t_pruned.evaluate(&ValOrTest::Test).unwrap();

    // retrain the same factors with fixed-rank DLRT
    let mut cfg_retrain = cfg_eval;
    cfg_retrain.epochs = 3;
    let mut t_retrain =
        Trainer::new(cfg_retrain).unwrap().with_factors(pruned, false).unwrap();
    let rec = t_retrain.run("it_retrain", |_| {}).unwrap();
    assert!(
        rec.test_acc > acc_raw + 0.05,
        "retraining did not recover accuracy: raw {acc_raw} -> retrained {}",
        rec.test_acc
    );
    // rank stayed fixed at 2 on the wide layers
    assert!(rec.final_ranks[0] == 2 && rec.final_ranks[1] == 2);
}

#[test]
fn resume_equivalence_pure_kls() {
    // train 1 epoch -> save -> load into a fresh trainer -> evaluate must
    // match the in-memory model exactly (same floats, not approximately)
    let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
    cfg.epochs = 1;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    t.run("it_resume_kls", |_| {}).unwrap();
    let (live_loss, live_acc) = t.evaluate(&ValOrTest::Test).unwrap();

    let dir = TestDir::new();
    let path = dir.join("kls.json");
    save_network(&path, &t.model).unwrap();
    let (arch, layers) = load_network(&path).unwrap();
    assert_eq!(arch, "mlp_tiny");
    let mut t2 = Trainer::new(cfg).unwrap();
    restore_network(&mut t2.model, layers).unwrap();
    let (loss, acc) = t2.evaluate(&ValOrTest::Test).unwrap();
    assert_eq!(loss, live_loss, "restored eval loss differs");
    assert_eq!(acc, live_acc, "restored eval accuracy differs");
}

#[test]
fn resume_equivalence_mixed_net() {
    // the same exact-resume guarantee for a TRP-style mixed net: the v2
    // checkpoint carries the dense layer verbatim
    let mut cfg = toy_mixed_cfg();
    cfg.epochs = 1;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    t.run("it_resume_mixed", |_| {}).unwrap();
    let (live_loss, live_acc) = t.evaluate(&ValOrTest::Test).unwrap();

    let dir = TestDir::new();
    let path = dir.join("mixed.json");
    save_network(&path, &t.model).unwrap();
    let (_, layers) = load_network(&path).unwrap();
    assert_eq!(layers[0].kind(), "dense");
    assert_eq!(layers[1].kind(), "dlrt");
    let mut t2 = Trainer::new(cfg).unwrap();
    restore_network(&mut t2.model, layers).unwrap();
    let (loss, acc) = t2.evaluate(&ValOrTest::Test).unwrap();
    assert_eq!(loss, live_loss, "restored mixed eval loss differs");
    assert_eq!(acc, live_acc, "restored mixed eval accuracy differs");
}

#[test]
fn checkpoint_rejects_layer_kind_mismatch() {
    // a v2 checkpoint of a mixed net must not restore into a net whose
    // layer_modes configure different kinds
    let mut cfg = toy_mixed_cfg();
    cfg.epochs = 1;
    let t = Trainer::new(cfg).unwrap();
    let dir = TestDir::new();
    let path = dir.join("mixed.json");
    save_network(&path, &t.model).unwrap();
    let (_, layers) = load_network(&path).unwrap();

    // pure-KLS trainer: layer 0 is 'dlrt' there, but the checkpoint says 'dense'
    let mut t2 = Trainer::new(toy_cfg(Mode::AdaptiveDlrt)).unwrap();
    let err = restore_network(&mut t2.model, layers).unwrap_err().to_string();
    assert!(err.contains("layer_modes"), "unhelpful mismatch error: {err}");
}

#[test]
fn dense_param_accounting_matches_arch() {
    let t = Trainer::new(toy_cfg(Mode::Dense)).unwrap();
    let (eval, train, dense) = t.param_accounting();
    // mlp_tiny: 32x64 + 32x32 + 10x32 (paper convention: no biases)
    let expect = 32 * 64 + 32 * 32 + 10 * 32;
    assert_eq!(dense, expect);
    assert_eq!(eval, expect, "a dense net evaluates at its dense size");
    assert_eq!(train, expect, "a dense net trains at its dense size");
}

#[test]
fn seeds_reproduce_runs_exactly() {
    let run = |seed: u64| {
        let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
        cfg.seed = seed;
        cfg.epochs = 2;
        let mut t = Trainer::new(cfg).unwrap();
        t.run("it_seed", |_| {}).unwrap()
    };
    let a = run(77);
    let b = run(77);
    assert_eq!(a.test_loss, b.test_loss);
    assert_eq!(a.final_ranks, b.final_ranks);
    let c = run(78);
    assert!(a.test_loss != c.test_loss || a.final_ranks != c.final_ranks);
}
