//! Full-stack training integration: the Trainer on every mode, rank
//! adaptation, pruning + retraining, and checkpoint round-trips — all on
//! the hermetic native backend. Uses the tiny arch + toy data so each test
//! completes in seconds.

use dlrt::baselines::svd_prune_factors;
use dlrt::baselines::DenseTrainer;
use dlrt::config::{presets, Config, DataSource, Integrator, Mode};
use dlrt::coordinator::{load_factors, save_factors, ModelState, Trainer, ValOrTest};
use dlrt::dlrt::OptKind;
use dlrt::linalg::{orthonormality_error, Rng};
use dlrt::util::testutil::TestDir;

fn toy_cfg(mode: Mode) -> Config {
    let mut cfg = presets::quickstart();
    cfg.mode = mode;
    cfg.epochs = 3;
    cfg.data = DataSource::Toy { n: 1_200 };
    cfg
}

#[test]
fn adaptive_dlrt_learns_toy_task_and_compresses() {
    let mut t = Trainer::new(toy_cfg(Mode::AdaptiveDlrt)).unwrap();
    let rec = t.run("it_adaptive", |_| {}).unwrap();
    assert!(
        rec.test_acc > 0.80,
        "adaptive DLRT should learn the toy task (acc {})",
        rec.test_acc
    );
    // ranks must have dropped below the init rank 16 on the wide layers
    assert!(rec.final_ranks[0] < 16, "no compression happened: {:?}", rec.final_ranks);
    // pinned classifier head stays at full rank 10
    assert_eq!(*rec.final_ranks.last().unwrap(), 10);
    // loss history is broadly decreasing
    let first = rec.epochs.first().unwrap().train_loss;
    let last = rec.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn fixed_dlrt_and_dense_and_vanilla_all_train() {
    for (mode, min_acc) in
        [(Mode::FixedDlrt, 0.7), (Mode::Dense, 0.8), (Mode::Vanilla, 0.6)]
    {
        let mut cfg = toy_cfg(mode);
        cfg.fixed_rank = 8;
        if mode == Mode::Vanilla {
            // vanilla needs a gentler lr (ill-conditioning is the point of Fig.4)
            cfg.integrator = Integrator::Adam;
            cfg.lr = 0.005;
        }
        let mut t = Trainer::new(cfg).unwrap();
        let rec = t.run("it_mode", |_| {}).unwrap();
        assert!(
            rec.test_acc > min_acc,
            "{mode:?} failed to learn (acc {})",
            rec.test_acc
        );
    }
}

#[test]
fn integrator_preserves_orthonormality_through_real_graphs() {
    let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
    cfg.paranoid = true; // integrator self-checks every step
    cfg.epochs = 2;
    let mut t = Trainer::new(cfg).unwrap();
    t.run("it_paranoid", |_| {}).unwrap();
    if let ModelState::Kls(k) = &t.model {
        for (i, f) in k.layers.iter().enumerate() {
            assert!(
                orthonormality_error(&f.u) < 1e-3,
                "layer {i}: U drifted off the Stiefel manifold"
            );
            assert!(orthonormality_error(&f.v) < 1e-3, "layer {i}: V drifted");
        }
    } else {
        panic!("expected KLS model");
    }
}

#[test]
fn rank_freeze_stops_adaptation() {
    let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
    cfg.epochs = 3;
    cfg.freeze_rank_after_epochs = 1;
    let mut t = Trainer::new(cfg).unwrap();
    let mut rank_history: Vec<Vec<usize>> = Vec::new();
    t.run("it_freeze", |e| rank_history.push(e.ranks.clone())).unwrap();
    // after the freeze epoch, ranks must be constant
    assert_eq!(rank_history[1], rank_history[2], "ranks changed after freeze");
}

#[test]
fn svd_prune_collapses_then_retraining_recovers() {
    // Table 8's mechanism at toy scale: truncation destroys accuracy,
    // fixed-rank DLRT retraining restores it.
    let mut cfg = toy_cfg(Mode::Dense);
    cfg.epochs = 3;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let dense_rec = t.run("it_dense_base", |_| {}).unwrap();
    assert!(dense_rec.test_acc > 0.85);

    let dense = match &t.model {
        ModelState::Dense(d) => d,
        _ => panic!("expected dense model"),
    };
    let pruned = svd_prune_factors(dense, 2); // aggressive rank-2 truncation

    // evaluate the raw truncation (no retraining)
    let mut cfg_eval = cfg.clone();
    cfg_eval.mode = Mode::FixedDlrt;
    let t_pruned = Trainer::new(cfg_eval.clone()).unwrap().with_factors(pruned.clone(), false).unwrap();
    let (_, acc_raw) = t_pruned.evaluate(&ValOrTest::Test).unwrap();

    // retrain the same factors with fixed-rank DLRT
    let mut cfg_retrain = cfg_eval;
    cfg_retrain.epochs = 3;
    let mut t_retrain =
        Trainer::new(cfg_retrain).unwrap().with_factors(pruned, false).unwrap();
    let rec = t_retrain.run("it_retrain", |_| {}).unwrap();
    assert!(
        rec.test_acc > acc_raw + 0.05,
        "retraining did not recover accuracy: raw {acc_raw} -> retrained {}",
        rec.test_acc
    );
    // rank stayed fixed at 2 on the wide layers
    assert!(rec.final_ranks[0] == 2 && rec.final_ranks[1] == 2);
}

#[test]
fn checkpoints_roundtrip_through_trainer() {
    let mut t = Trainer::new(toy_cfg(Mode::AdaptiveDlrt)).unwrap();
    let rec = t.run("it_ckpt", |_| {}).unwrap();
    let dir = TestDir::new();
    let path = dir.join("model.json");
    let layers = match &t.model {
        ModelState::Kls(k) => k.layers.clone(),
        _ => unreachable!(),
    };
    save_factors(&path, "mlp_tiny", &layers).unwrap();
    let (arch, loaded) = load_factors(&path).unwrap();
    assert_eq!(arch, "mlp_tiny");
    let t2 = Trainer::new(toy_cfg(Mode::AdaptiveDlrt)).unwrap().with_factors(loaded, false).unwrap();
    let (_, acc) = t2.evaluate(&ValOrTest::Test).unwrap();
    assert!(
        (acc - rec.test_acc).abs() < 1e-5,
        "checkpoint eval mismatch: {acc} vs {}",
        rec.test_acc
    );
}

#[test]
fn dense_trainer_param_count_matches_arch() {
    let rt = dlrt::runtime::Runtime::native();
    let mut rng = Rng::new(0);
    let d = DenseTrainer::new(&rt, "mlp_tiny", OptKind::Sgd, &mut rng).unwrap();
    // mlp_tiny: 32x64 + 32x32 + 10x32 (paper convention: no biases)
    assert_eq!(d.param_count(), 32 * 64 + 32 * 32 + 10 * 32);
}

#[test]
fn seeds_reproduce_runs_exactly() {
    let run = |seed: u64| {
        let mut cfg = toy_cfg(Mode::AdaptiveDlrt);
        cfg.seed = seed;
        cfg.epochs = 2;
        let mut t = Trainer::new(cfg).unwrap();
        t.run("it_seed", |_| {}).unwrap()
    };
    let a = run(77);
    let b = run(77);
    assert_eq!(a.test_loss, b.test_loss);
    assert_eq!(a.final_ranks, b.final_ranks);
    let c = run(78);
    assert!(a.test_loss != c.test_loss || a.final_ranks != c.final_ranks);
}
