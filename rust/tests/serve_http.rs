//! Front-door integration suite (DESIGN.md §11): replica-count and
//! batch-composition invariance of served logits, the raw-HTTP contract
//! of every endpoint, atomic hot-swap, and graceful-shutdown semantics.
//!
//! The invariance claims are *bitwise*: every serving kernel is
//! row-independent, so a request's logits must be identical whether it
//! rode alone or in a full batch, on one replica or four.

use dlrt::dlrt::LowRankFactors;
use dlrt::linalg::{Matrix, Rng};
use dlrt::runtime::Runtime;
use dlrt::serve::{
    DrainPolicy, Engine, EngineConfig, FrozenLayer, FrozenModel, HttpConfig, HttpServer, Outcome,
    ShedReason,
};
use dlrt::util::testutil::TestDir;
use dlrt::util::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// A small `mlp_tiny`-shaped frozen model (two low-rank layers + a dense
/// head) whose weights depend only on `seed`.
fn tiny_model(seed: u64) -> FrozenModel {
    let rt = Runtime::native();
    let arch = rt.arch("mlp_tiny").unwrap();
    let mut rng = Rng::new(seed);
    FrozenModel {
        arch_name: "mlp_tiny".into(),
        arch,
        layers: vec![
            FrozenLayer::from_factors(&LowRankFactors::random(32, 64, 6, &mut rng)),
            FrozenLayer::from_factors(&LowRankFactors::random(32, 32, 6, &mut rng)),
            FrozenLayer::Dense { w: rng.normal_matrix(10, 32), bias: vec![0.0; 10] },
        ],
    }
}

fn serve_cfg(replicas: usize) -> EngineConfig {
    // Eager drain: sequential solo requests would otherwise wait out
    // their SLO slack hoping for co-riders. The SloSlack waiting path is
    // covered by the queue's ManualClock tests and benches/serve_http.rs;
    // the generous SLO means nothing expires on a loaded CI box.
    EngineConfig {
        batch_cap: 8,
        replicas,
        slo: Duration::from_secs(30),
        policy: DrainPolicy::Eager,
        ..EngineConfig::default()
    }
}

/// Logits must be placement- and batch-composition-invariant: bitwise
/// identical to the direct batch forward at replicas ∈ {1, 2, 4}, via
/// both coalesced (`infer_many`) and per-request (`infer`) admission.
#[test]
fn replica_parity_is_bitwise_at_1_2_4() {
    let model = tiny_model(41);
    let mut rng = Rng::new(42);
    let x = rng.normal_matrix(24, 64);
    let direct = model.forward_logits(&x).unwrap();
    let rows: Vec<Vec<f32>> = (0..x.rows()).map(|i| x.row(i).to_vec()).collect();
    for replicas in [1usize, 2, 4] {
        // coalesced through the default SloSlack policy: 24 rows admitted
        // under one lock drain as full batch_cap batches (a full batch
        // never waits), whatever the replica count
        let engine = Engine::start(
            model.clone(),
            EngineConfig {
                batch_cap: 8,
                replicas,
                slo: Duration::from_secs(30),
                ..EngineConfig::default()
            },
        )
        .unwrap();
        let preds = engine.infer_many(rows.clone()).unwrap();
        for (i, p) in preds.iter().enumerate() {
            assert_eq!(
                p.logits,
                direct.row(i).to_vec(),
                "replicas={replicas}: coalesced row {i} logits drifted"
            );
        }
        engine.shutdown();

        // solo requests through eager drains: same bitwise answers
        let engine = Engine::start(model.clone(), serve_cfg(replicas)).unwrap();
        for (i, row) in rows.iter().enumerate().take(6) {
            let p = engine.infer(row.clone()).unwrap();
            assert_eq!(
                p.logits,
                direct.row(i).to_vec(),
                "replicas={replicas}: solo row {i} logits drifted"
            );
        }
        engine.shutdown();
    }
}

// ---------------------------------------------------------------------
// Minimal raw-HTTP client: one keep-alive connection, Content-Length
// framing — exactly the subset the server speaks.
// ---------------------------------------------------------------------

struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to the serve port");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client { reader: BufReader::new(stream) }
    }

    fn send(&mut self, method: &str, path: &str, body: &str) {
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let stream = self.reader.get_mut();
        stream.write_all(req.as_bytes()).expect("writing request");
        stream.flush().unwrap();
    }

    fn read_response(&mut self) -> (u16, String) {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("reading status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line: {line:?}"));
        let mut content_length = 0usize;
        loop {
            line.clear();
            self.reader.read_line(&mut line).expect("reading header");
            let l = line.trim();
            if l.is_empty() {
                break;
            }
            if let Some((k, v)) = l.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().expect("content-length");
                }
            }
        }
        let mut body = vec![0u8; content_length];
        self.reader.read_exact(&mut body).expect("reading body");
        (status, String::from_utf8(body).expect("utf-8 body"))
    }

    fn request(&mut self, method: &str, path: &str, body: &str) -> (u16, Json) {
        self.send(method, path, body);
        let (status, body) = self.read_response();
        (status, Json::parse(&body).unwrap_or_else(|e| panic!("bad JSON body {body:?}: {e:#}")))
    }
}

fn infer_body(features: &[f32]) -> String {
    Json::obj(vec![("features", Json::f32_array(features))]).to_string()
}

/// The full export-equivalence loop over the wire: save → load → serve →
/// `POST /infer` answers are bitwise equal to the frozen file's own batch
/// forward; `/healthz` and `/stats` report the serving contract.
#[test]
fn http_infer_matches_frozen_eval_bitwise() {
    let dir = TestDir::new();
    let path = dir.join("m_frozen.json");
    tiny_model(51).save(&path).unwrap();
    let rt = Runtime::native();
    let model = FrozenModel::load(&path, &rt).unwrap();
    let mut rng = Rng::new(52);
    let x = rng.normal_matrix(6, 64);
    let direct = model.forward_logits(&x).unwrap();
    let labels = direct.argmax_rows();

    let engine = Arc::new(Engine::start(model, serve_cfg(2)).unwrap());
    let server =
        HttpServer::bind(Arc::clone(&engine), "127.0.0.1:0", HttpConfig::default()).unwrap();
    let mut client = Client::connect(server.addr());

    // the serving contract, before any traffic
    let (status, health) = client.request("GET", "/healthz", "");
    assert_eq!(status, 200, "{health:?}");
    assert!(health.req("ok").unwrap().as_bool().unwrap());
    assert_eq!(health.req("arch").unwrap().as_str().unwrap(), "mlp_tiny");
    assert_eq!(health.req("input_dim").unwrap().as_usize().unwrap(), 64);
    assert_eq!(health.req("num_classes").unwrap().as_usize().unwrap(), 10);
    // dense head reports min(m, n) = 10
    assert_eq!(health.req("ranks").unwrap().to_usize_vec().unwrap(), vec![6, 6, 10]);

    // keep-alive: all rows over one connection, each answer bitwise
    for i in 0..x.rows() {
        let (status, reply) = client.request("POST", "/infer", &infer_body(x.row(i)));
        assert_eq!(status, 200, "row {i}: {reply:?}");
        let logits = reply.req("logits").unwrap().to_f32_vec().unwrap();
        assert_eq!(logits, direct.row(i).to_vec(), "row {i}: HTTP logits drifted");
        assert_eq!(reply.req("label").unwrap().as_usize().unwrap(), labels[i]);
    }

    let (status, stats) = client.request("GET", "/stats", "");
    assert_eq!(status, 200);
    assert_eq!(stats.req("requests").unwrap().as_usize().unwrap(), x.rows());
    assert_eq!(stats.req("shed_total").unwrap().as_usize().unwrap(), 0);
    let hist = stats.req("batch_hist").unwrap().as_arr().unwrap();
    let drains: usize =
        hist.iter().map(|b| b.req("drains").unwrap().as_usize().unwrap()).sum();
    assert_eq!(drains, stats.req("batches").unwrap().as_usize().unwrap());

    // protocol errors are clean statuses, not hangs or resets
    let (status, _) = client.request("GET", "/no_such_endpoint", "");
    assert_eq!(status, 404);
    let (status, _) = client.request("DELETE", "/infer", "");
    assert_eq!(status, 405);
    let mut fresh = Client::connect(server.addr());
    let (status, err) = fresh.request("POST", "/infer", "this is not json");
    assert_eq!(status, 400, "{err:?}");
    let mut fresh = Client::connect(server.addr());
    let (status, err) = fresh.request("POST", "/infer", &infer_body(&[1.0, 2.0]));
    assert_eq!(status, 400, "wrong feature width must 400: {err:?}");
    let mut fresh = Client::connect(server.addr());
    let (status, err) =
        fresh.request("POST", "/infer", r#"{"features": [0.0], "slo_ms": -5}"#);
    assert_eq!(status, 400, "negative slo_ms must 400: {err:?}");

    // front-door shutdown leaves the engine alive for embedded callers
    server.shutdown();
    assert!(engine.infer(x.row(0).to_vec()).is_ok());
    engine.shutdown();
}

/// `POST /reload` atomically swaps the model (subsequent answers are
/// bitwise the new model's), and refuses contract-breaking replacements
/// with a 409 while continuing to serve the old model.
#[test]
fn http_reload_hot_swaps_and_rejects_mismatch() {
    let dir = TestDir::new();
    let (a_path, b_path, alien_path) =
        (dir.join("a_frozen.json"), dir.join("b_frozen.json"), dir.join("alien_frozen.json"));
    tiny_model(61).save(&a_path).unwrap();
    let model_b = tiny_model(62);
    model_b.save(&b_path).unwrap();
    let mut alien = tiny_model(63);
    alien.arch_name = "not_mlp_tiny".into();
    alien.save(&alien_path).unwrap();

    let rt = Runtime::native();
    let mut rng = Rng::new(64);
    let x = rng.normal_matrix(3, 64);
    let direct_a = FrozenModel::load(&a_path, &rt).unwrap().forward_logits(&x).unwrap();
    let direct_b = FrozenModel::load(&b_path, &rt).unwrap().forward_logits(&x).unwrap();

    let engine =
        Arc::new(Engine::start(FrozenModel::load(&a_path, &rt).unwrap(), serve_cfg(1)).unwrap());
    let server =
        HttpServer::bind(Arc::clone(&engine), "127.0.0.1:0", HttpConfig::default()).unwrap();
    let mut client = Client::connect(server.addr());

    let (status, reply) = client.request("POST", "/infer", &infer_body(x.row(0)));
    assert_eq!(status, 200);
    assert_eq!(reply.req("logits").unwrap().to_f32_vec().unwrap(), direct_a.row(0).to_vec());

    let reload = |client: &mut Client, path: &std::path::Path| {
        let body =
            Json::obj(vec![("path", Json::str(path.to_str().unwrap()))]).to_string();
        client.request("POST", "/reload", &body)
    };
    let (status, reply) = reload(&mut client, &b_path);
    assert_eq!(status, 200, "{reply:?}");
    assert_eq!(reply.req("ranks").unwrap().to_usize_vec().unwrap(), vec![6, 6, 10]);
    for i in 0..x.rows() {
        let (status, reply) = client.request("POST", "/infer", &infer_body(x.row(i)));
        assert_eq!(status, 200);
        assert_eq!(
            reply.req("logits").unwrap().to_f32_vec().unwrap(),
            direct_b.row(i).to_vec(),
            "row {i} not served by the swapped model"
        );
    }

    // contract violations: wrong arch and unloadable path both 409 and
    // leave the engine on the last good model
    let (status, err) = reload(&mut client, &alien_path);
    assert_eq!(status, 409, "{err:?}");
    assert!(err.req("error").unwrap().as_str().unwrap().contains("hot-swap rejected"));
    let (status, _) = reload(&mut client, &dir.join("missing_frozen.json"));
    assert_eq!(status, 409);
    let (status, reply) = client.request("POST", "/infer", &infer_body(x.row(0)));
    assert_eq!(status, 200);
    assert_eq!(reply.req("logits").unwrap().to_f32_vec().unwrap(), direct_b.row(0).to_vec());

    server.shutdown();
    engine.shutdown();
}

/// Hot-swap mid-stream never mixes layers inside one batch: while one
/// thread flips the model between two snapshots, every concurrent answer
/// is bitwise equal to one of the two direct forwards — never a blend.
#[test]
fn concurrent_hot_swap_never_mixes_models() {
    let model_a = tiny_model(71);
    let model_b = tiny_model(72);
    let mut rng = Rng::new(73);
    let x = rng.normal_matrix(4, 64);
    let direct_a = model_a.forward_logits(&x).unwrap();
    let direct_b = model_b.forward_logits(&x).unwrap();

    let engine = Arc::new(
        Engine::start(
            model_a.clone(),
            EngineConfig {
                batch_cap: 4,
                replicas: 2,
                slo: Duration::from_secs(30),
                policy: DrainPolicy::Eager,
                ..EngineConfig::default()
            },
        )
        .unwrap(),
    );

    let clients: Vec<_> = (0..3usize)
        .map(|c| {
            let engine = Arc::clone(&engine);
            let rows: Vec<Vec<f32>> = (0..x.rows()).map(|i| x.row(i).to_vec()).collect();
            let expect: Vec<(Vec<f32>, Vec<f32>)> = (0..x.rows())
                .map(|i| (direct_a.row(i).to_vec(), direct_b.row(i).to_vec()))
                .collect();
            std::thread::spawn(move || {
                for round in 0..30 {
                    for (i, row) in rows.iter().enumerate() {
                        let p = engine.infer(row.clone()).unwrap();
                        let (ref ea, ref eb) = expect[i];
                        assert!(
                            p.logits == *ea || p.logits == *eb,
                            "client {c} round {round} row {i}: blended logits — \
                             hot-swap mixed models inside a batch"
                        );
                    }
                }
            })
        })
        .collect();
    for k in 0..40 {
        let next = if k % 2 == 0 { model_b.clone() } else { model_a.clone() };
        engine.swap_model(next).unwrap();
        std::thread::yield_now();
    }
    for c in clients {
        c.join().expect("client thread");
    }
    engine.shutdown();
}

/// An engine that is shutting down sheds over HTTP with a 503 and the
/// `shutting_down` reason — deterministic, since the queue is closed
/// before the request arrives.
#[test]
fn http_sheds_503_when_engine_is_down() {
    let model = tiny_model(81);
    let row = vec![0.5f32; 64];
    let engine = Arc::new(Engine::start(model, serve_cfg(1)).unwrap());
    let server =
        HttpServer::bind(Arc::clone(&engine), "127.0.0.1:0", HttpConfig::default()).unwrap();
    engine.shutdown();
    let mut client = Client::connect(server.addr());
    let (status, reply) = client.request("POST", "/infer", &infer_body(&row));
    assert_eq!(status, 503, "{reply:?}");
    assert_eq!(reply.req("error").unwrap().as_str().unwrap(), "shed");
    assert_eq!(reply.req("reason").unwrap().as_str().unwrap(), "shutting_down");
    let (status, stats) = client.request("GET", "/stats", "");
    assert_eq!(status, 200);
    assert_eq!(stats.req("shed_shutdown").unwrap().as_usize().unwrap(), 1);
    server.shutdown();
}

/// Direct engine-level shed sanity: a closed engine's tickets resolve as
/// `Shed(ShuttingDown)` rather than hanging (the HTTP 503 above rides on
/// exactly this path).
#[test]
fn closed_engine_tickets_resolve_without_hanging() {
    let engine = Engine::start(tiny_model(91), serve_cfg(1)).unwrap();
    engine.shutdown();
    match engine.enqueue(vec![0.0; 64], Some(Duration::from_millis(5))).unwrap().wait() {
        Outcome::Shed(ShedReason::ShuttingDown) => {}
        other => panic!("expected shutdown shed, got {other:?}"),
    }
}
