//! Steady-state allocation accounting for the convolutional training path
//! (DESIGN.md §9): the LeNet step routes im2col patch matrices, maxpool
//! argmax indices, packed GEMM panels, and batch matrices through the
//! global scratch pool, so a sharded conv training step must allocate
//! nothing fresh once warmed up.
//!
//! Single #[test] on purpose — this binary owns its process-global pool
//! counters (see rust/tests/steady_state.rs for the MLP-path twin).

use dlrt::config::{presets, DataSource};
use dlrt::coordinator::Trainer;
use dlrt::data::{Batch, Batcher};
use dlrt::util::scratch;

#[test]
fn conv_training_step_allocates_nothing_in_steady_state() {
    // fig4_dlrt pins a global fixed rank: no adaptive augmentation, so
    // every tape/workspace shape is constant from the first step on.
    let mut cfg = presets::fig4_dlrt(16);
    cfg.data = DataSource::Mnist { root: "data/__steady_state_conv__".into(), n_synth: 400 };
    cfg.seed = 42;
    let cfg = presets::with_grad_shards(cfg, 2);
    let arch = cfg.arch.clone();
    let lr = cfg.lr;

    let mut t = Trainer::new(cfg).unwrap();
    let batch_cap = t.rt.batch_cap(&arch).unwrap();
    let mut batcher = Batcher::new(t.split.train.len(), batch_cap, true, 7);
    let batches: Vec<Batch> = batcher.epoch(&t.split.train).collect();
    assert!(!batches.is_empty(), "synthetic MNIST yields no full batch");

    let pool = scratch::global();
    let mut step = 0usize;
    let mut flat_streak = 0usize;
    while flat_streak < 2 && step < 25 {
        let before = pool.fresh_allocs();
        t.model.step(&t.rt, &batches[step % batches.len()], lr).unwrap();
        step += 1;
        if pool.fresh_allocs() == before {
            flat_streak += 1;
        } else {
            flat_streak = 0;
        }
    }
    assert!(
        flat_streak >= 2,
        "scratch pool never reached steady state on the conv path: fresh \
         allocs still growing after {step} warmup steps"
    );

    let baseline = pool.fresh_allocs();
    for i in 0..5 {
        t.model.step(&t.rt, &batches[(step + i) % batches.len()], lr).unwrap();
    }
    assert_eq!(
        pool.fresh_allocs(),
        baseline,
        "steady-state conv training step performed fresh pool-class heap \
         allocations (im2col/maxpool/matmul path must be fully recycled)"
    );
    assert!(pool.reuses() > 0, "pool recorded no reuse at all — accounting is broken");
}
