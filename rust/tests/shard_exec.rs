//! Shard-execution suite: the data-parallel step executor must (a) match
//! the single-shard gradients/loss within float-reduction tolerance at any
//! shard count, (b) be bitwise-reproducible across reruns at a fixed shard
//! count, and (c) be an exact passthrough at `grad_shards = 1` — the
//! committed `regression_trace` snapshot locks (c) end-to-end through the
//! trainer, this file locks it at the backend boundary.
//!
//! The property net mirrors the TRP shape the refactor exists for: a
//! dense conv prefix (LeNet's two conv layers as full kernel matrices)
//! feeding an adaptive low-rank fully-connected tail.

use dlrt::backend::{ComputeBackend, GradPhase, GradsOut, LayerGrads, LayerParams, NativeBackend};
use dlrt::baselines::he_normal;
use dlrt::config::{presets, DataSource};
use dlrt::coordinator::Trainer;
use dlrt::data::Batch;
use dlrt::dlrt::LowRankFactors;
use dlrt::linalg::{Matrix, Rng};
use dlrt::runtime::Runtime;

/// Dense-conv prefix + adaptive low-rank tail on the `lenet` geometry:
/// conv 20x25, conv 50x500 (dense kernels) | fc 500x800, fc 10x500
/// (factored).
struct MixedNet {
    w0: Matrix,
    b0: Vec<f32>,
    w1: Matrix,
    b1: Vec<f32>,
    f2: LowRankFactors,
    f3: LowRankFactors,
}

impl MixedNet {
    fn new(seed: u64) -> MixedNet {
        let mut rng = Rng::new(seed);
        let mut net = MixedNet {
            w0: he_normal(20, 25, &mut rng),
            b0: (0..20).map(|_| 0.1 * rng.normal()).collect(),
            w1: he_normal(50, 500, &mut rng),
            b1: (0..50).map(|_| 0.1 * rng.normal()).collect(),
            f2: LowRankFactors::random(500, 800, 16, &mut rng),
            f3: LowRankFactors::random(10, 500, 10, &mut rng),
        };
        for b in net.f2.bias.iter_mut().chain(net.f3.bias.iter_mut()) {
            *b = 0.1 * rng.normal();
        }
        net
    }

    fn params(&self) -> Vec<LayerParams<'_>> {
        vec![
            LayerParams::Dense { w: &self.w0, bias: &self.b0 },
            LayerParams::Dense { w: &self.w1, bias: &self.b1 },
            LayerParams::Factored {
                u: &self.f2.u,
                s: &self.f2.s,
                v: &self.f2.v,
                bias: &self.f2.bias,
            },
            LayerParams::Factored {
                u: &self.f3.u,
                s: &self.f3.s,
                v: &self.f3.v,
                bias: &self.f3.bias,
            },
        ]
    }
}

/// A 24-row MNIST-shaped batch with a padding tail and one fractional
/// weight, so the shard reduction's Σw-weighting is actually exercised.
fn lenet_batch(seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let bsz = 24;
    let count = 20;
    let mut b = Batch {
        x: (0..bsz * 784).map(|_| rng.normal()).collect(),
        y: (0..bsz).map(|_| rng.below(10) as i32).collect(),
        w: vec![1.0; bsz],
        count,
    };
    for i in count..bsz {
        b.w[i] = 0.0;
        for v in &mut b.x[i * 784..(i + 1) * 784] {
            *v = 0.0;
        }
    }
    b.w[5] = 0.5;
    b
}

fn rel_close(name: &str, a: f32, b: f32, tol: f32) {
    assert!(
        (a - b).abs() <= tol * b.abs().max(1e-3),
        "{name}: {a} vs {b} (rel tol {tol})"
    );
}

fn mat_close(name: &str, a: &Matrix, b: &Matrix, tol: f32) {
    assert_eq!(a.shape(), b.shape(), "{name}: shape mismatch");
    let denom = b.fro_norm().max(1e-6);
    let dist = a.fro_dist(b);
    assert!(dist <= tol * denom, "{name}: ‖Δ‖ = {dist} vs ‖ref‖ = {denom} (rel tol {tol})");
}

fn vec_close(name: &str, a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len(), "{name}: arity mismatch");
    let denom = b.iter().map(|&x| (x * x) as f64).sum::<f64>().sqrt().max(1e-6) as f32;
    let dist = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt() as f32;
    assert!(dist <= tol * denom, "{name}: ‖Δ‖ = {dist} vs ‖ref‖ = {denom} (rel tol {tol})");
}

fn assert_grads_close(k: usize, sharded: &GradsOut, direct: &GradsOut, tol: f32) {
    rel_close(&format!("loss (shards={k})"), sharded.loss, direct.loss, 1e-5);
    // half-integer weights: the correct count is exactly representable
    assert_eq!(sharded.ncorrect, direct.ncorrect, "ncorrect (shards={k})");
    assert_eq!(sharded.layers.len(), direct.layers.len());
    for (l, (a, b)) in sharded.layers.iter().zip(&direct.layers).enumerate() {
        let tag = |t: &str| format!("layer {l} {t} (shards={k})");
        match (a, b) {
            (LayerGrads::Kl { dk, dl }, LayerGrads::Kl { dk: rk, dl: rl }) => {
                mat_close(&tag("∂K"), dk, rk, tol);
                mat_close(&tag("∂L"), dl, rl, tol);
            }
            (LayerGrads::S { ds, db }, LayerGrads::S { ds: rs, db: rb }) => {
                mat_close(&tag("∂S"), ds, rs, tol);
                vec_close(&tag("∂b"), db, rb, tol);
            }
            (LayerGrads::Dense { dw, db }, LayerGrads::Dense { dw: rw, db: rb }) => {
                mat_close(&tag("∂W"), dw, rw, tol);
                vec_close(&tag("∂b"), db, rb, tol);
            }
            (
                LayerGrads::TwoFactor { du, dv, db },
                LayerGrads::TwoFactor { du: ru, dv: rv, db: rb },
            ) => {
                mat_close(&tag("∂U"), du, ru, tol);
                mat_close(&tag("∂V"), dv, rv, tol);
                vec_close(&tag("∂b"), db, rb, tol);
            }
            (LayerGrads::None, LayerGrads::None) => {}
            _ => panic!("layer {l}: sharded and direct runs returned different variants"),
        }
    }
}

fn grads_bitwise_eq(a: &GradsOut, b: &GradsOut) -> bool {
    if a.loss.to_bits() != b.loss.to_bits() || a.ncorrect.to_bits() != b.ncorrect.to_bits() {
        return false;
    }
    let bits = |m: &Matrix, n: &Matrix| {
        m.shape() == n.shape()
            && m.data().iter().zip(n.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    let vbits = |p: &[f32], q: &[f32]| {
        p.len() == q.len() && p.iter().zip(q).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    a.layers.len() == b.layers.len()
        && a.layers.iter().zip(&b.layers).all(|(x, y)| match (x, y) {
            (LayerGrads::Kl { dk, dl }, LayerGrads::Kl { dk: a1, dl: a2 }) => {
                bits(dk, a1) && bits(dl, a2)
            }
            (LayerGrads::S { ds, db }, LayerGrads::S { ds: a1, db: a2 }) => {
                bits(ds, a1) && vbits(db, a2)
            }
            (LayerGrads::Dense { dw, db }, LayerGrads::Dense { dw: a1, db: a2 }) => {
                bits(dw, a1) && vbits(db, a2)
            }
            (
                LayerGrads::TwoFactor { du, dv, db },
                LayerGrads::TwoFactor { du: a1, dv: a2, db: a3 },
            ) => bits(du, a1) && bits(dv, a2) && vbits(db, a3),
            (LayerGrads::None, LayerGrads::None) => true,
            _ => false,
        })
}

#[test]
fn sharded_grads_match_single_shard_on_mixed_conv_net() {
    let net = MixedNet::new(0xA11CE);
    let params = net.params();
    let batch = lenet_batch(7);
    let direct = Runtime::native();
    for phase in [GradPhase::Kl, GradPhase::S] {
        let reference = direct.grads("lenet", &params, phase, &batch).unwrap();
        for k in [2usize, 3, 4] {
            let rt = Runtime::native().with_grad_shards(k).unwrap();
            let sharded = rt.grads("lenet", &params, phase, &batch).unwrap();
            assert_grads_close(k, &sharded, &reference, 1e-4);
        }
    }
}

#[test]
fn sharded_grads_are_bitwise_deterministic_at_fixed_shard_count() {
    let net = MixedNet::new(0xDE7);
    let params = net.params();
    let batch = lenet_batch(8);
    // two runs on one runtime (exercises recycled shard buffers) and one
    // on a fresh runtime (no hidden per-instance state): all bitwise-equal
    let rt = Runtime::native().with_grad_shards(3).unwrap();
    let a = rt.grads("lenet", &params, GradPhase::Kl, &batch).unwrap();
    let b = rt.grads("lenet", &params, GradPhase::Kl, &batch).unwrap();
    let fresh = Runtime::native().with_grad_shards(3).unwrap();
    let c = fresh.grads("lenet", &params, GradPhase::Kl, &batch).unwrap();
    assert!(grads_bitwise_eq(&a, &b), "rerun on the same runtime drifted");
    assert!(grads_bitwise_eq(&a, &c), "rerun on a fresh runtime drifted");
}

#[test]
fn grad_shards_one_is_bitwise_identical_to_the_direct_backend() {
    let net = MixedNet::new(0xF00D);
    let params = net.params();
    let batch = lenet_batch(9);
    let be = NativeBackend::new();
    let rt = Runtime::native(); // default grad_shards = 1
    assert_eq!(rt.grad_shards(), 1);
    for phase in [GradPhase::Kl, GradPhase::S] {
        let through_rt = rt.grads("lenet", &params, phase, &batch).unwrap();
        let direct = be.grads("lenet", &params, phase, &batch).unwrap();
        assert!(
            grads_bitwise_eq(&through_rt, &direct),
            "the grad_shards = 1 passthrough is not bitwise-exact ({phase:?})"
        );
    }
}

#[test]
fn sharded_evaluate_matches_single_shard() {
    // Network::evaluate rides the executor: the row-sharded forward with
    // its fixed-order two-scalar reduce must agree with the direct backend
    // within float-reduction tolerance at any shard count
    let net = MixedNet::new(0xE7A1);
    let params = net.params();
    let batch = lenet_batch(11);
    let be = NativeBackend::new();
    let reference = be.forward("lenet", &params, &batch).unwrap();
    for k in [2usize, 3, 4] {
        let rt = Runtime::native().with_grad_shards(k).unwrap();
        let sharded = rt.forward("lenet", &params, &batch).unwrap();
        rel_close(&format!("eval loss (shards={k})"), sharded.loss, reference.loss, 1e-4);
        // half-integer weights: the correct count is exactly representable
        assert_eq!(sharded.ncorrect, reference.ncorrect, "ncorrect (shards={k})");
    }
}

#[test]
fn sharded_evaluate_is_bitwise_deterministic_at_fixed_shard_count() {
    let net = MixedNet::new(0xBEEF);
    let params = net.params();
    let batch = lenet_batch(12);
    let rt = Runtime::native().with_grad_shards(3).unwrap();
    let a = rt.forward("lenet", &params, &batch).unwrap();
    let b = rt.forward("lenet", &params, &batch).unwrap();
    let fresh = Runtime::native().with_grad_shards(3).unwrap();
    let c = fresh.forward("lenet", &params, &batch).unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "eval rerun on the same runtime drifted");
    assert_eq!(a.ncorrect.to_bits(), b.ncorrect.to_bits());
    assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "eval rerun on a fresh runtime drifted");
    assert_eq!(a.ncorrect.to_bits(), c.ncorrect.to_bits());
}

#[test]
fn evaluate_shard_one_is_bitwise_passthrough() {
    let net = MixedNet::new(0xCAFE);
    let params = net.params();
    let batch = lenet_batch(13);
    let be = NativeBackend::new();
    let rt = Runtime::native(); // default grad_shards = 1
    let through_rt = rt.forward("lenet", &params, &batch).unwrap();
    let direct = be.forward("lenet", &params, &batch).unwrap();
    assert_eq!(
        through_rt.loss.to_bits(),
        direct.loss.to_bits(),
        "the grad_shards = 1 evaluate passthrough is not bitwise-exact"
    );
    assert_eq!(through_rt.ncorrect.to_bits(), direct.ncorrect.to_bits());
}

#[test]
fn sharded_training_run_learns_and_stays_close_to_unsharded() {
    // end-to-end: the same seeded 2-epoch toy run under grad_shards 1 and
    // 2 — both must learn, and the sharded trajectory must stay within
    // float-reduction drift of the unsharded one
    let run = |shards: usize| {
        let mut cfg = presets::with_grad_shards(presets::quickstart(), shards);
        cfg.epochs = 2;
        cfg.seed = 1234;
        cfg.data = DataSource::Toy { n: 800 };
        let mut t = Trainer::new(cfg).unwrap();
        t.run(&format!("shard{shards}"), |_| {}).unwrap()
    };
    let base = run(1);
    let sharded = run(2);
    for rec in [&base, &sharded] {
        let first = rec.epochs.first().unwrap().train_loss;
        let last = rec.epochs.last().unwrap().train_loss;
        assert!(last < first, "training did not reduce loss ({first} -> {last})");
    }
    rel_close(
        "epoch-0 train loss, sharded vs unsharded",
        sharded.epochs[0].train_loss,
        base.epochs[0].train_loss,
        0.02,
    );
    rel_close("final test loss, sharded vs unsharded", sharded.test_loss, base.test_loss, 0.15);
}
