//! Steady-state allocation accounting for the dense/low-rank MLP training
//! path (DESIGN.md §9): after warmup, a sharded training step must draw
//! every matmul workspace, packing panel, and batch matrix from the global
//! scratch pool — zero fresh heap allocations in the hot path.
//!
//! This file intentionally holds a single #[test]: integration-test
//! binaries run in their own process, so the process-global pool counters
//! are not perturbed by unrelated tests.

use dlrt::config::{presets, Mode};
use dlrt::coordinator::Trainer;
use dlrt::data::{Batch, Batcher};
use dlrt::util::scratch;

#[test]
fn mlp_training_step_allocates_nothing_in_steady_state() {
    // FixedDlrt pins every rank so buffer shapes cannot grow after warmup
    // (adaptive rank augmentation would legitimately demand new sizes).
    let mut cfg = presets::quickstart();
    cfg.mode = Mode::FixedDlrt;
    cfg.fixed_rank = 16;
    let cfg = presets::with_grad_shards(cfg, 2);
    let arch = cfg.arch.clone();
    let lr = cfg.lr;

    let mut t = Trainer::new(cfg).unwrap();
    let batch_cap = t.rt.batch_cap(&arch).unwrap();
    let mut batcher = Batcher::new(t.split.train.len(), batch_cap, true, 7);
    let batches: Vec<Batch> = batcher.epoch(&t.split.train).collect();
    assert!(!batches.is_empty(), "toy dataset yields no full batch");

    // Warm up until the pool reaches its fixed point: two consecutive
    // steps with zero fresh allocations. The bound is generous — the
    // working set is a handful of distinct sizes per shard worker.
    let pool = scratch::global();
    let mut step = 0usize;
    let mut flat_streak = 0usize;
    while flat_streak < 2 && step < 25 {
        let before = pool.fresh_allocs();
        t.model.step(&t.rt, &batches[step % batches.len()], lr).unwrap();
        step += 1;
        if pool.fresh_allocs() == before {
            flat_streak += 1;
        } else {
            flat_streak = 0;
        }
    }
    assert!(
        flat_streak >= 2,
        "scratch pool never reached steady state: fresh allocs still \
         growing after {step} warmup steps"
    );

    let baseline = pool.fresh_allocs();
    for i in 0..5 {
        t.model.step(&t.rt, &batches[(step + i) % batches.len()], lr).unwrap();
    }
    assert_eq!(
        pool.fresh_allocs(),
        baseline,
        "steady-state MLP training step performed fresh pool-class heap \
         allocations (batch/matmul/packing path must be fully recycled)"
    );
    assert!(pool.reuses() > 0, "pool recorded no reuse at all — accounting is broken");
}
