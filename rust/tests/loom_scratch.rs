//! Loom model of the `ScratchPool` checkout/return protocol (DESIGN.md
//! §9/§10): concurrent `take`/`put` from shard workers must hand out
//! exclusive buffers, reinitialize every recycled checkout, and keep the
//! fresh/reuse accounting exact.
//!
//! Build with `RUSTFLAGS="--cfg loom" cargo test -p dlrt --test
//! loom_scratch`. Without `--cfg loom` this target compiles to an empty
//! test binary. The in-tree `loom` shim explores perturbed schedules
//! rather than the exhaustive DPOR search of upstream loom — see
//! rust/shims/loom and DESIGN.md §10 for the exact guarantees.
#![cfg(loom)]

use dlrt::util::scratch::{ScratchPool, MIN_POOL_LEN};
use loom::sync::Arc;
use loom::thread;

/// Two workers race take → stamp → verify → put on a pool holding one
/// recyclable buffer. If the pool ever handed the same buffer to both,
/// one worker's stamp would clobber the other's and the verify fails.
#[test]
fn concurrent_checkouts_never_alias() {
    loom::model(|| {
        let pool = Arc::new(ScratchPool::new());
        pool.put(vec![0.0f32; 256]);
        let workers: Vec<_> = (0..2)
            .map(|t| {
                let p = Arc::clone(&pool);
                thread::spawn(move || {
                    let mut b = p.take(256);
                    let stamp = (t + 1) as f32;
                    for v in b.iter_mut() {
                        *v = stamp;
                    }
                    thread::yield_now();
                    assert!(
                        b.iter().all(|&v| v == stamp),
                        "buffer aliased across concurrent checkouts"
                    );
                    p.put(b);
                })
            })
            .collect();
        for w in workers {
            w.join().expect("worker");
        }
        // Every pool-class take is accounted exactly once, races included.
        assert_eq!(pool.fresh_allocs() + pool.reuses(), 2);
    });
}

/// A worker returns a NaN-poisoned buffer while the main thread takes:
/// whichever buffer the taker gets (fresh or the recycled poisoned one),
/// it must come back fully zeroed.
#[test]
fn recycled_buffers_are_reinitialized_under_races() {
    loom::model(|| {
        let pool = Arc::new(ScratchPool::new());
        let mut dirty = pool.take(MIN_POOL_LEN);
        for v in dirty.iter_mut() {
            *v = f32::NAN;
        }
        let p2 = Arc::clone(&pool);
        let returner = thread::spawn(move || p2.put(dirty));
        let got = pool.take(MIN_POOL_LEN);
        assert_eq!(got.len(), MIN_POOL_LEN);
        assert!(got.iter().all(|&v| v == 0.0), "recycled checkout leaked values");
        returner.join().expect("returner");
        pool.put(got);
    });
}

/// Checkout is exclusive: a buffer leaves the free list while in use, so
/// two live checkouts are always distinct allocations.
#[test]
fn checkout_is_exclusive() {
    loom::model(|| {
        let pool = ScratchPool::new();
        pool.put(vec![0.0f32; 128]);
        let a = pool.take(128);
        let b = pool.take(128);
        assert_ne!(a.as_ptr(), b.as_ptr(), "double hand-out of a pooled buffer");
        pool.put(a);
        pool.put(b);
    });
}
