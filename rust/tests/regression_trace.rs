//! Deterministic regression trace: one seeded, few-step TRP-LeNet run
//! whose per-epoch loss trace is snapshot-compared, so a future kernel or
//! refactor PR cannot silently drift the training numerics.
//!
//! The snapshot is self-bootstrapping: the first run on a checkout trains
//! the trace **twice**, asserts the two runs agree bitwise (the
//! determinism contract of DESIGN.md §5), writes
//! `tests/snapshots/trp_lenet_trace.json`, and passes; every later run
//! compares against the file with a small relative tolerance. The
//! drift-vs-history check therefore only bites once a snapshot is
//! committed — run the suite once and commit the generated file (and
//! after an *intentional* numerics change, delete it and commit the
//! regenerated one with the PR that changed the math). Until then the
//! bootstrap branch still pins within-build determinism, which is what a
//! fresh checkout can honestly verify.

use dlrt::config::{presets, DataSource};
use dlrt::coordinator::Trainer;
use dlrt::util::Json;
use std::path::PathBuf;

/// Relative tolerance per compared scalar. Tight enough to catch a changed
/// contraction or reduction order, loose enough for cross-platform libm
/// differences (exp/ln in the softmax).
const REL_TOL: f64 = 2e-3;

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/snapshots/trp_lenet_trace.json")
}

/// One seeded trace run: mixed TRP net, 2 epochs x 3 steps on synthetic
/// MNIST; the bogus data root pins the synthetic generator even when a
/// real MNIST copy exists locally.
fn run_trace() -> (Vec<(f64, f64, f64)>, Vec<usize>) {
    let mut cfg = presets::trp_lenet(0.15);
    cfg.epochs = 2;
    cfg.seed = 42;
    cfg.max_steps_per_epoch = 3;
    cfg.data = DataSource::Mnist { root: "data/__regression_trace__".into(), n_synth: 1_500 };
    let mut t = Trainer::new(cfg).unwrap();
    let rec = t.run("regression_trace", |_| {}).unwrap();
    assert_eq!(rec.epochs.len(), 2);
    let trace = rec
        .epochs
        .iter()
        .map(|e| (e.train_loss as f64, e.train_loss_after_kl as f64, e.val_loss as f64))
        .collect();
    (trace, rec.final_ranks.clone())
}

#[test]
fn trp_lenet_loss_trace_matches_snapshot() {
    let (got, got_ranks) = run_trace();

    let path = snapshot_path();
    if !path.exists() {
        // bootstrap: no history to diff against, so pin what a fresh
        // checkout *can* verify — the trace is bitwise reproducible —
        // then write the snapshot for future runs to compare with
        let (again, again_ranks) = run_trace();
        assert_eq!(got, again, "seeded trace is not deterministic within one build");
        assert_eq!(got_ranks, again_ranks, "seeded ranks are not deterministic");
        let epochs = got.iter().map(|&(tl, tak, vl)| {
            Json::obj(vec![
                ("train_loss", Json::num(tl)),
                ("train_loss_after_kl", Json::num(tak)),
                ("val_loss", Json::num(vl)),
            ])
        });
        let doc = Json::obj(vec![
            ("config", Json::str("trp_lenet tau=0.15 seed=42 2x3 steps n=1500")),
            ("epochs", Json::arr(epochs)),
            ("final_ranks", Json::usize_array(&got_ranks)),
        ]);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        eprintln!(
            "regression_trace: wrote new snapshot {} — commit it to pin the numerics",
            path.display()
        );
        return;
    }

    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let want = doc.req("epochs").unwrap().as_arr().unwrap();
    assert_eq!(
        want.len(),
        got.len(),
        "snapshot has {} epochs, run produced {} — regenerate the snapshot \
         if the trace config changed intentionally",
        want.len(),
        got.len()
    );
    let close = |name: &str, epoch: usize, a: f64, b: f64| {
        assert!(
            (a - b).abs() <= REL_TOL * b.abs().max(1e-3),
            "numeric drift in {name} at epoch {epoch}: ran {a}, snapshot {b} \
             (rel tol {REL_TOL}); if this PR changed the math on purpose, \
             delete {} and commit the regenerated snapshot",
            snapshot_path().display()
        );
    };
    for (epoch, (w, &(tl, tak, vl))) in want.iter().zip(&got).enumerate() {
        close("train_loss", epoch, tl, w.req("train_loss").unwrap().as_f64().unwrap());
        close(
            "train_loss_after_kl",
            epoch,
            tak,
            w.req("train_loss_after_kl").unwrap().as_f64().unwrap(),
        );
        close("val_loss", epoch, vl, w.req("val_loss").unwrap().as_f64().unwrap());
    }
    let want_ranks = doc.req("final_ranks").unwrap().to_usize_vec().unwrap();
    assert_eq!(
        got_ranks, want_ranks,
        "final ranks drifted from the snapshot — truncation decisions changed"
    );
}
