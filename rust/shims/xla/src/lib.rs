//! API stub of the PJRT-backed `xla` crate (the `xla-rs` surface the
//! coordinator's `--features xla` path compiles against).
//!
//! The offline image has no PJRT plugin, so this crate keeps the *type*
//! surface compilable and the host-side pieces (literal packing/unpacking)
//! fully functional, while every operation that would need a real XLA
//! runtime — client creation, HLO parsing, compilation, execution — returns
//! a descriptive [`XlaError`]. Deployments with a PJRT toolchain swap in the
//! real crate via a `[patch]` entry; no source changes are needed
//! (DESIGN.md §2, backend policy).

use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `{e:?}` formatting.
#[derive(Debug)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what} requires a real PJRT-backed `xla` crate; this build uses the in-tree API stub \
         (patch in `xla-rs` + a PJRT plugin to execute compiled artifacts)"
    ))
}

/// Element types the coordinator packs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Marker for scalar/vector element access, mirroring `xla::NativeType`.
pub trait NativeType: Copy {
    const DTYPE: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
    fn write_le(self, out: &mut Vec<u8>);
}

impl NativeType for f32 {
    const DTYPE: ElementType = ElementType::F32;
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl NativeType for i32 {
    const DTYPE: ElementType = ElementType::S32;
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

/// Host tensor value. Fully functional: this is plain host memory.
#[derive(Debug, Clone)]
pub struct Literal {
    dtype: ElementType,
    shape: Vec<usize>,
    /// Little-endian raw element bytes (empty for tuples).
    data: Vec<u8>,
    /// Non-empty when this literal is a tuple.
    elements: Vec<Literal>,
}

impl Literal {
    /// Build from a shape and raw little-endian bytes (4-byte elements).
    pub fn create_from_shape_and_untyped_data(
        dtype: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        if data.len() != elems * 4 {
            return Err(XlaError(format!(
                "literal data size {} does not match shape {shape:?} ({} bytes expected)",
                data.len(),
                elems * 4
            )));
        }
        Ok(Literal { dtype, shape: shape.to_vec(), data: data.to_vec(), elements: vec![] })
    }

    /// Scalar constructor.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut data = Vec::with_capacity(4);
        v.write_le(&mut data);
        Literal { dtype: T::DTYPE, shape: vec![], data, elements: vec![] }
    }

    /// Wrap literals into a tuple (the shape compiled graphs return).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dtype: ElementType::F32, shape: vec![], data: vec![], elements }
    }

    /// Decompose a tuple literal into its elements (by-value, mirroring
    /// the upstream crate's signature).
    #[allow(clippy::wrong_self_convention)]
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        if self.elements.is_empty() {
            return Err(XlaError("not a tuple literal".into()));
        }
        Ok(self.elements)
    }

    /// Copy out the elements as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::DTYPE != self.dtype {
            return Err(XlaError(format!(
                "dtype mismatch: literal holds {:?}, asked for {:?}",
                self.dtype,
                T::DTYPE
            )));
        }
        Ok(self.data.chunks_exact(4).map(T::from_le).collect())
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if self.data.len() < 4 {
            return Err(XlaError("empty literal".into()));
        }
        if T::DTYPE != self.dtype {
            return Err(XlaError("dtype mismatch in get_first_element".into()));
        }
        Ok(T::from_le(&self.data[..4]))
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn element_type(&self) -> ElementType {
        self.dtype
    }
}

/// Parsed HLO module (stub: carries nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {}", path.as_ref().display())))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("reading a device buffer"))
    }
}

/// Compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing a compiled graph"))
    }
}

/// PJRT client (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating a PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling an XLA computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        for x in xs {
            x.write_le(&mut bytes);
        }
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(7i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].get_first_element::<i32>().unwrap(), 7);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/none.hlo.txt").is_err());
    }

    #[test]
    fn shape_validation() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0u8; 8])
                .is_err()
        );
    }
}
