//! In-tree, dependency-free subset of the `anyhow` crate API.
//!
//! The offline build environment has no crates.io access (DESIGN.md §3), so
//! the workspace vendors the exact surface the coordinator uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros. The implementation is a plain context-message stack —
//! no backtraces, no downcasting — which is all the crate's error handling
//! relies on. Swapping in the real `anyhow` is a one-line `Cargo.toml`
//! change; no source edits are required.

use std::fmt;

/// Drop-in replacement for `anyhow::Error`: an outermost message plus the
/// chain of underlying causes, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an additional layer of context (becomes the new outermost
    /// message, like `anyhow::Error::context`).
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` joins the whole chain, mirroring anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("unknown error"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// `?`-conversion from any standard error type, capturing its source chain.
/// `Error` itself deliberately does not implement `std::error::Error`, so
/// this blanket impl cannot overlap the identity `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Drop-in replacement for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Private conversion trait so [`Context`] works both on results carrying
/// standard errors and on results already carrying [`Error`] — the same
/// local-negative-reasoning trick the real `anyhow` uses.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Drop-in replacement for `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error branch.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    /// Attach a lazily-built context message to the error branch.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("root cause {}", 7))
    }

    #[test]
    fn display_shows_outermost_context() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause 7");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn ensure_and_bail_forms() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            ensure!(x != 1);
            if x == 2 {
                bail!("two is right out");
            }
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(-1).unwrap_err().to_string(), "negative: -1");
        assert!(check(1).unwrap_err().to_string().contains("condition failed"));
        assert!(check(2).is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(5).with_context(|| "unused").unwrap(), 5);
    }
}
