//! Offline stand-in for [tokio-rs/loom](https://github.com/tokio-rs/loom).
//!
//! The workspace builds hermetically with no network dependencies
//! (DESIGN.md §3), so this shim provides the subset of the loom 0.7 API
//! that `rust/tests/loom_scratch.rs` uses: [`model`], [`thread`], and
//! [`sync`] wrappers around std primitives. Instead of loom's exhaustive
//! DPOR schedule exploration it runs the model body many times
//! (`LOOM_MAX_ITERS`, default 200) and injects yields at every
//! synchronization point, seeded differently per iteration, to shake out
//! interleavings. Real loom is drop-in compatible: point the
//! `[target.'cfg(loom)'.dependencies]` entry in `rust/Cargo.toml` at the
//! upstream crate and the model gains exhaustive coverage with no source
//! changes (see DESIGN.md §10 for the documented skip conditions).

use std::sync::atomic::{AtomicU32, Ordering as O};

/// Per-iteration schedule seed; [`maybe_yield`] derives its decisions
/// from this so each model iteration perturbs different sync points.
static SCHEDULE: AtomicU32 = AtomicU32::new(1);

fn maybe_yield() {
    // xorshift step on the shared schedule word: cheap, deterministic
    // per-iteration-seed, and different threads observe different slices
    // of the sequence, which is exactly the perturbation we want.
    let mut x = SCHEDULE.load(O::Relaxed);
    x ^= x << 13;
    x ^= x >> 17;
    x ^= x << 5;
    SCHEDULE.store(x, O::Relaxed);
    if x % 3 == 0 {
        std::thread::yield_now();
    }
}

/// Run `f` repeatedly with perturbed schedules. Loom-compatible entry
/// point; panics from the model body propagate (failing the test).
pub fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    let iters: u32 = std::env::var("LOOM_MAX_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    for i in 0..iters {
        SCHEDULE.store(i.wrapping_mul(2654435761).wrapping_add(1) | 1, O::Relaxed);
        f();
    }
}

pub mod thread {
    pub use std::thread::{yield_now, JoinHandle};

    pub fn spawn<F, T>(f: F) -> std::thread::JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        super::maybe_yield();
        std::thread::spawn(move || {
            super::maybe_yield();
            f()
        })
    }
}

pub mod sync {
    pub use std::sync::{Arc, LockResult, MutexGuard, WaitTimeoutResult};

    /// std Mutex with yield injection on every acquire.
    #[derive(Debug, Default)]
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        pub fn new(t: T) -> Mutex<T> {
            Mutex(std::sync::Mutex::new(t))
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            super::maybe_yield();
            let r = self.0.lock();
            super::maybe_yield();
            r
        }

        pub fn try_lock(&self) -> std::sync::TryLockResult<MutexGuard<'_, T>> {
            super::maybe_yield();
            self.0.try_lock()
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.0.into_inner()
        }
    }

    /// std Condvar with yield injection around wait/notify. Timed waits
    /// are clamped to 1ms: the serve queue re-checks its drain condition
    /// on every wakeup, so an early timeout is indistinguishable from a
    /// spurious wake, and perturbed-schedule iterations stay fast.
    #[derive(Debug, Default)]
    pub struct Condvar(std::sync::Condvar);

    impl Condvar {
        pub fn new() -> Condvar {
            Condvar(std::sync::Condvar::new())
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            super::maybe_yield();
            let r = self.0.wait(guard);
            super::maybe_yield();
            r
        }

        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            dur: std::time::Duration,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            super::maybe_yield();
            let r = self.0.wait_timeout(guard, dur.min(std::time::Duration::from_millis(1)));
            super::maybe_yield();
            r
        }

        pub fn notify_one(&self) {
            super::maybe_yield();
            self.0.notify_one();
        }

        pub fn notify_all(&self) {
            super::maybe_yield();
            self.0.notify_all();
        }
    }

    pub mod atomic {
        pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
    }
}
